"""RWKV-6 (Finch) blocks: data-dependent-decay time-mix + channel-mix.

Training/prefill uses the chunked linear-attention form (scan over chunks of
length ``CHUNK``; matrix-valued per-head state carried in f32). All decay
exponents are arranged to be <= 0 so every exp() is safe:

  o_t  = r_t^T S_{t-1} + (r_t . (u o k_t)) v_t
  S_t  = diag(w_t) S_{t-1} + k_t v_t^T,   w_t = exp(-exp(dec_t)) in (0,1)

Chunked (local indices 0..L-1, incoming state S):
  cum[t]   = sum_{s<=t} logw_s          (inclusive cumsum, <=0)
  pex[t]   = cum[t] - logw[t]           (exclusive)
  o_inter  = (r_t o exp(pex[t])) @ S
  A[t,s]   = sum_i r[t,i] k[s,i] exp(pex[t,i] - cum[s,i])   (s < t)
  o_diag   = (sum_i r[t,i] u_i k[t,i]) v_t
  S'       = exp(cum[L-1]) o S + sum_s (exp(cum[L-1]-cum[s]) o k_s) v_s^T
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import PD

CHUNK = 64
LORA_MIX = 32
LORA_DECAY = 64


def best_chunk(s: int, chunk: int) -> int:
    """Largest divisor of s that is <= chunk (keeps state exact at chunk ends)."""
    for c in range(min(chunk, s), 0, -1):
        if s % c == 0:
            return c
    return 1


def _ln(x, w, b, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    return ((x - mu) * lax.rsqrt(var + eps) * w + b).astype(dt)


def group_norm_heads(o, w, b, eps=1e-5):
    """o: (B, S, H, hd); normalize per head over hd."""
    dt = o.dtype
    o = o.astype(jnp.float32)
    mu = o.mean(-1, keepdims=True)
    var = o.var(-1, keepdims=True)
    o = (o - mu) * lax.rsqrt(var + eps)
    b_, s_, h_, hd_ = o.shape
    o = o.reshape(b_, s_, h_ * hd_) * w + b
    return o.astype(dt)


def time_mix_defs(cfg, prefix=()) -> dict:
    d = cfg.d_model
    ps = tuple(s for s, _ in prefix)
    pa = tuple(a for _, a in prefix)
    h = d // cfg.rwkv_head_dim
    f32 = jnp.float32
    return {
        "mu_x": PD(ps + (d,), pa + (None,), init="zeros", dtype=f32),
        "mu_wkvrg": PD(ps + (5, d), pa + (None, None), init="zeros", dtype=f32),
        "lora_A": PD(ps + (d, 5 * LORA_MIX), pa + ("embed", None)),
        "lora_B": PD(ps + (5, LORA_MIX, d), pa + (None, None, None), init="zeros"),
        "w0": PD(ps + (d,), pa + (None,), init="zeros", dtype=f32),
        "dec_A": PD(ps + (d, LORA_DECAY), pa + ("embed", None)),
        "dec_B": PD(ps + (LORA_DECAY, d), pa + (None, None), init="zeros"),
        "u": PD(ps + (h, cfg.rwkv_head_dim), pa + (None, None), init="zeros", dtype=f32),
        "w_r": PD(ps + (d, d), pa + ("embed", "heads")),
        "w_k": PD(ps + (d, d), pa + ("embed", "heads")),
        "w_v": PD(ps + (d, d), pa + ("embed", "heads")),
        "w_g": PD(ps + (d, d), pa + ("embed", "heads")),
        "w_o": PD(ps + (d, d), pa + ("heads", "embed_out")),
        "gn_w": PD(ps + (d,), pa + (None,), init="ones", dtype=f32),
        "gn_b": PD(ps + (d,), pa + (None,), init="zeros", dtype=f32),
    }


def channel_mix_defs(cfg, prefix=()) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ps = tuple(s for s, _ in prefix)
    pa = tuple(a for _, a in prefix)
    return {
        "mu_k": PD(ps + (d,), pa + (None,), init="zeros", dtype=jnp.float32),
        "mu_r": PD(ps + (d,), pa + (None,), init="zeros", dtype=jnp.float32),
        "w_k": PD(ps + (d, f), pa + ("embed", "ff")),
        "w_v": PD(ps + (f, d), pa + ("ff", "embed_out")),
        "w_r": PD(ps + (d, d), pa + ("embed", "heads")),
    }


def _token_shift(x, prev):
    """prev: (B, d) last token of the previous segment (zeros at start)."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _mix_inputs(p, x, prev):
    """Finch data-dependent token-shift mixing. Returns dict of mixed inputs."""
    xx = _token_shift(x, prev) - x  # (B,S,d)
    x_base = x + xx * p["mu_x"].astype(x.dtype)
    lora = jnp.tanh(x_base @ p["lora_A"])  # (B,S,5*32)
    lora = lora.reshape(*lora.shape[:-1], 5, LORA_MIX)
    dyn = jnp.einsum("bsfm,fmd->bsfd", lora, p["lora_B"])  # (B,S,5,d)
    mixed = x[..., None, :] + xx[..., None, :] * (
        p["mu_wkvrg"].astype(x.dtype) + dyn
    )  # (B,S,5,d)
    names = ("w", "k", "v", "r", "g")
    return {n: mixed[..., i, :] for i, n in enumerate(names)}


def wkv_chunked(r, k, v, logw, u, state, chunk=CHUNK):
    """r,k,v,logw: (B,S,H,hd); u: (H,hd); state: (B,H,hd,hd) f32.

    Returns (o: (B,S,H,hd), new_state). logw <= 0.
    """
    b, s, h, hd = r.shape
    chunk = best_chunk(s, chunk)
    n = s // chunk

    def to_chunks(x):
        return x.reshape(b, n, chunk, h, hd).transpose(1, 0, 3, 2, 4)  # (n,B,H,L,hd)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, logw.astype(jnp.float32)))
    causal = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)  # strict lower

    @jax.checkpoint  # recompute intra-chunk decay tensors in bwd: without
    # this the scan stacks (n_chunks, B, H, L, L, hd) f32 residuals
    # (5.4 GiB/layer on rwkv6-3b train_4k — EXPERIMENTS.md §Perf-1)
    def body(S, xs):
        rb, kb, vb, wb = xs  # (B,H,L,hd)
        rb32, kb32 = rb.astype(jnp.float32), kb.astype(jnp.float32)
        vb32 = vb.astype(jnp.float32)
        cum = jnp.cumsum(wb, axis=2)  # (B,H,L,hd) <= 0
        pex = cum - wb
        r_dec = rb32 * jnp.exp(pex)  # decayed receptance
        o_inter = jnp.einsum("bhli,bhij->bhlj", r_dec, S)
        # intra-chunk pairwise decays (B,H,L,L,hd); exponent <= 0 for s < t.
        # (bf16 storage for this tensor was tried and REFUTED on the CPU
        # validation path: XLA:CPU upcasts bf16 so converts added traffic,
        # 9.78->9.99 s — EXPERIMENTS.md §Perf-1 iteration 4.)
        dmat = pex[:, :, :, None, :] - cum[:, :, None, :, :]
        e = jnp.exp(jnp.where(causal[None, None, :, :, None], dmat, -jnp.inf))
        a = jnp.einsum("bhti,bhsi,bhtsi->bhts", rb32, kb32, e)
        o_intra = jnp.einsum("bhts,bhsj->bhtj", a, vb32)
        diag = jnp.einsum("bhti,hi->bht", rb32 * kb32, u.astype(jnp.float32))
        o_diag = diag[..., None] * vb32
        o = o_inter + o_intra + o_diag
        # state to end of chunk
        k_dec = kb32 * jnp.exp(cum[:, :, -1:, :] - cum)
        S_new = jnp.exp(cum[:, :, -1, :])[..., None] * S + jnp.einsum(
            "bhsi,bhsj->bhij", k_dec, vb32
        )
        return S_new, o

    state, oc = lax.scan(body, state, (rc, kc, vc, wc))
    o = oc.transpose(1, 0, 3, 2, 4).reshape(b, s, h, hd)
    return o.astype(r.dtype), state


def wkv_step(r, k, v, logw, u, state):
    """Single decode step. r,k,v,logw: (B,H,hd); state (B,H,hd,hd) f32."""
    r32, k32, v32 = (x.astype(jnp.float32) for x in (r, k, v))
    kv = k32[..., :, None] * v32[..., None, :]  # (B,H,hd,hd)
    o = jnp.einsum("bhi,bhij->bhj", r32, state + u.astype(jnp.float32)[..., None] * kv)
    state = jnp.exp(logw.astype(jnp.float32))[..., None] * state + kv
    return o.astype(r.dtype), state


def time_mix_apply(p, x, cfg, state):
    """x: (B,S,d). state: dict(S=(B,H,hd,hd) f32, prev=(B,d)) or None (zeros).

    Returns (out, new_state).
    """
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    if state is None:
        prev = jnp.zeros((b, d), x.dtype)
        S = jnp.zeros((b, h, hd, hd), jnp.float32)
    else:
        prev, S = state["prev"], state["S"]
    m = _mix_inputs(p, x, prev)
    dec = p["w0"].astype(jnp.float32) + (
        jnp.tanh(m["w"] @ p["dec_A"]) @ p["dec_B"]
    ).astype(jnp.float32)
    logw = -jnp.exp(dec)  # (B,S,d) <= 0
    r = (m["r"] @ p["w_r"]).reshape(b, s, h, hd)
    k = (m["k"] @ p["w_k"]).reshape(b, s, h, hd)
    v = (m["v"] @ p["w_v"]).reshape(b, s, h, hd)
    g = jax.nn.silu(m["g"] @ p["w_g"])
    o, S = wkv_chunked(r, k, v, logw.reshape(b, s, h, hd), p["u"], S, chunk=CHUNK)
    o = group_norm_heads(o, p["gn_w"], p["gn_b"]).reshape(b, s, d)
    out = (o * g) @ p["w_o"]
    return out, {"prev": x[:, -1, :], "S": S}


def channel_mix_apply(p, x, cfg, state):
    b, s, d = x.shape
    prev = jnp.zeros((b, d), x.dtype) if state is None else state["prev"]
    xx = _token_shift(x, prev) - x
    xk = x + xx * p["mu_k"].astype(x.dtype)
    xr = x + xx * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    out = jax.nn.sigmoid(xr @ p["w_r"]) * (k @ p["w_v"])
    return out, {"prev": x[:, -1, :]}
