"""Attention: chunked (XLA path), ring (context-parallel), sharded decode.

Three implementations, one math:
  * ``attention_chunked`` — q-chunked masked attention; the XLA path used for
    training/prefill (Pallas flash kernel is the TPU-target twin, validated
    against the same reference in tests).
  * ``ring_attention`` — context-parallel attention for archs whose head
    counts don't divide the model axis. KV blocks stream around the 'model'
    ring via ppermute with online-softmax accumulation: this is the xDFS
    parallel-channel pipeline applied to attention (each ring step is one
    in-flight "file block"; the (m, l, acc) carry is the circular buffer).
  * ``decode_attention_sharded`` — flash-decoding over a sequence-sharded KV
    cache (batch over 'data', seq over 'model'), combining per-shard partial
    softmax statistics with psum. Used by every decode cell.

All softmax math is f32; GQA is einsum-grouped (no kv materialized repeat).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import softcap

NEG_INF = -1e30


def _scores(q, k, scale, cap):
    """q: (B,Sq,Hkv,G,D)  k: (B,Sk,Hkv,D) -> (B,Hkv,G,Sq,Sk) f32."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32)
    return softcap(s * scale, cap)


def _gqa_split(q, num_kv_heads):
    b, s, hq, d = q.shape
    return q.reshape(b, s, num_kv_heads, hq // num_kv_heads, d)


def attention_chunked(
    q,
    k,
    v,
    *,
    scale: float,
    q_offset: int = 0,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
    chunk: int = 1024,
):
    """Causal (optionally sliding-window) GQA attention, scanned over q chunks.

    q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D). Returns (B, Sq, Hq, D).
    Peak memory O(chunk * Sk) instead of O(Sq * Sk).
    """
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    chunk = min(chunk, sq)
    sq_pad = ((sq + chunk - 1) // chunk) * chunk
    if sq_pad != sq:  # pad q; padded rows are computed then sliced away
        q = jnp.pad(q, ((0, 0), (0, sq_pad - sq), (0, 0), (0, 0)))
    n = sq_pad // chunk
    qg = _gqa_split(q, hkv)  # (B,Sq_pad,Hkv,G,D)
    qg = qg.reshape(b, n, chunk, hkv, hq // hkv, d).transpose(1, 0, 2, 3, 4, 5)
    kpos = jnp.arange(k.shape[1])[None, :]

    @jax.checkpoint  # recompute scores in bwd: never stack f32 score chunks
    def body(_, xs):
        qc, i = xs
        qpos = q_offset + i * chunk + jnp.arange(chunk)[:, None]
        s = _scores(qc, k, scale, logit_cap)
        mask = kpos <= qpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
        return None, o

    _, outs = lax.scan(body, None, (qg, jnp.arange(n)))
    # (n, B, chunk, Hkv, G, D) -> (B, Sq, Hq, D)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq_pad, hq, d)
    return out[:, :sq]


# ---------------------------------------------------------------------------
# Ring attention (context parallel) — xDFS channel pipeline over the KV axis
# ---------------------------------------------------------------------------


def ring_attention(
    q,
    k,
    v,
    *,
    axis_name: str,
    scale: float,
    logit_cap: Optional[float] = None,
):
    """Causal GQA ring attention. Called INSIDE shard_map.

    q, k, v: LOCAL blocks (B, S_loc, H*, D); the sequence axis is sharded over
    ``axis_name``. Each of the n_shards ring steps overlaps one KV-block
    ppermute ("channel transfer") with one partial-attention compute, exactly
    the MTEDP schedule: communication of block t+1 hides behind compute of
    block t under XLA async collective scheduling.
    """
    n = lax.axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    b, s_loc, hq, d = q.shape
    hkv = k.shape[2]
    qg = _gqa_split(q, hkv)
    qpos = idx * s_loc + jnp.arange(s_loc)[:, None]  # global q positions

    m0 = jnp.full((b, hkv, hq // hkv, s_loc), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, hq // hkv, s_loc), jnp.float32)
    acc0 = jnp.zeros((b, s_loc, hkv, hq // hkv, d), jnp.float32)
    perm = [(i, (i + 1) % n) for i in range(n)]

    def body(carry, step):
        kb, vb, m, l, acc = carry
        owner = (idx - step) % n
        kpos = owner * s_loc + jnp.arange(s_loc)[None, :]
        s = _scores(qg, kb, scale, logit_cap)  # (B,Hkv,G,Sq,Sk)
        s = jnp.where((kpos <= qpos)[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr.transpose(0, 3, 1, 2)[..., None] + jnp.einsum(
            "bhgqk,bkhd->bqhgd", p.astype(vb.dtype), vb
        ).astype(jnp.float32)
        kb = lax.ppermute(kb, axis_name, perm)
        vb = lax.ppermute(vb, axis_name, perm)
        return (kb, vb, m_new, l, acc), None

    (_, _, _, l, acc), _ = lax.scan(body, (k, v, m0, l0, acc0), jnp.arange(n))
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return out.reshape(b, s_loc, hq, d).astype(q.dtype)


def gathered_kv_attention(
    q,
    k,
    v,
    *,
    axis_name: str,
    scale: float,
    logit_cap: Optional[float] = None,
    chunk: int = 128,
):
    """Context-parallel attention via KV all-gather. Called INSIDE shard_map.

    q, k, v: LOCAL blocks (B, S_loc, H*, D), sequence sharded over
    ``axis_name``. KV is all-gathered (cheap: KV is Hkv*D wide) and local q
    attends to the full sequence with the q-chunked kernel. Compared to the
    ring schedule this keeps NO per-step softmax state across a scan, so the
    backward pass (under remat) stays O(chunk * S) instead of
    O(n_steps * S_loc * S_loc) saved buffers — measured 3.5 GiB/step/layer on
    arctic-480b (EXPERIMENTS.md §Dry-run). Preferred for S <= ~64k; the ring
    path remains for longer sequences.
    """
    idx = lax.axis_index(axis_name)
    s_loc = q.shape[1]
    k_full = lax.all_gather(k, axis_name, axis=1, tiled=True)
    v_full = lax.all_gather(v, axis_name, axis=1, tiled=True)
    return attention_chunked(
        q,
        k_full,
        v_full,
        scale=scale,
        q_offset=idx * s_loc,
        logit_cap=logit_cap,
        chunk=chunk,
    )


# ---------------------------------------------------------------------------
# Sharded decode (flash-decoding over seq-sharded KV cache)
# ---------------------------------------------------------------------------


def decode_attention_sharded(
    q,
    k_cache,
    v_cache,
    new_k,
    new_v,
    pos,
    *,
    axis_name: str,
    scale: float,
    window: Optional[int] = None,
    rolling: bool = False,
    logit_cap: Optional[float] = None,
):
    """One-token decode against a sequence-sharded KV cache. INSIDE shard_map.

    q: (B, Hq, D); k_cache/v_cache: (B, S_loc, Hkv, D) local slice of the
    cache; new_k/new_v: (B, Hkv, D) this step's KV (written into whichever
    shard owns position ``pos``); pos: scalar global position.

    rolling=True: the cache is a rolling window of capacity window (sharded
    over axis_name); slot for global position p is p % window.

    Returns (out (B,Hq,D), k_cache, v_cache).
    """
    n = lax.axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    b, s_loc, hkv, d = k_cache.shape
    hq = q.shape[1]
    lo = idx * s_loc

    # --- predicated insert of the new token's KV into the owning shard -----
    # rolling caches have global capacity == window == n * s_loc
    slot = pos % (n * s_loc) if rolling else pos
    local_slot = jnp.clip(slot - lo, 0, s_loc - 1)
    mine = (slot >= lo) & (slot < lo + s_loc)

    def insert(cache, new):
        cur = lax.dynamic_slice(cache, (0, local_slot, 0, 0), (b, 1, hkv, d))
        upd = jnp.where(mine, new[:, None], cur)
        return lax.dynamic_update_slice(cache, upd, (0, local_slot, 0, 0))

    k_cache = insert(k_cache, new_k)
    v_cache = insert(v_cache, new_v)

    # --- masked partial attention over the local slice ----------------------
    qg = q.reshape(b, hkv, hq // hkv, d)
    s = jnp.einsum(
        "bhgd,bkhd->bhgk", qg, k_cache, preferred_element_type=jnp.float32
    )
    s = softcap(s * scale, logit_cap)
    slots = lo + jnp.arange(s_loc)[None, :]  # (1, S_loc) storage slots
    if rolling:
        # global position stored in slot s: largest kpos <= pos with kpos%W==s
        kpos = pos - ((pos - slots) % window)
        valid = kpos >= 0
    else:
        kpos = slots
        valid = kpos <= pos
        if window is not None:
            valid &= (pos - kpos) < window
    s = jnp.where(valid[None, None], s, NEG_INF)

    m = s.max(axis=-1)
    # psum-combine partial softmax statistics across shards
    m_g = lax.pmax(m, axis_name)
    p = jnp.exp(s - m_g[..., None])
    l = lax.psum(p.sum(axis=-1), axis_name)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache)
    o = lax.psum(o.astype(jnp.float32), axis_name)
    out = (o / jnp.maximum(l, 1e-30)[..., None]).reshape(b, hq, d)
    return out.astype(q.dtype), k_cache, v_cache
