"""Decoder LM assembling every assigned block family.

Layers are organized as a GROUPED scan: the layer pattern (e.g. 'lg' for
gemma2, 'rrl' for recurrentgemma, 'g'/'k' homogeneous) defines a super-block
that repeats num_layers // len(pattern) times (+ an unscanned epilogue for the
remainder). Every sub-block position has a static kind, so caches/windows are
static per position while HLO stays small (one scan, not L unrolled layers).

Modes: 'train' (loss-ready hidden states), 'prefill' (build KV/recurrent
caches, last-position logits), 'decode' (one token against caches;
sequence-sharded flash-decoding attention).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import rglru as rglru_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.attention import (
    gathered_kv_attention,
    attention_chunked,
    decode_attention_sharded,
    ring_attention,
)
from repro.models.layers import (
    PD,
    abstract_params,
    init_params,
    mlp_apply,
    mlp_defs,
    rms_norm,
    rope,
    softcap,
)
from repro.models.moe import moe_apply, moe_defs
from repro.runtime.shard import Policy, make_policy

CACHE_PAD = 256


def aux_zero():
    return (jnp.zeros((), jnp.float32),) * 3


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


class LM:
    def __init__(self, cfg: ModelConfig, mesh, kind: str, plain: bool = False):
        self.cfg = cfg
        self.mesh = mesh
        self.kind = kind
        self.policy: Policy = make_policy(cfg, mesh, kind, plain=plain)
        self.pattern = cfg.layer_pattern
        self.n_scan = cfg.num_layers // len(self.pattern)
        self.rem = cfg.num_layers % len(self.pattern)
        self.vocab_pad = (
            cfg.padded_vocab() if cfg.vocab_size % max(self.policy.msize, 16) else cfg.vocab_size
        )
        self.defs = self._build_defs()

    # ------------------------------------------------------------------
    # parameter definitions
    # ------------------------------------------------------------------
    def _norm_init(self) -> str:
        return "zeros" if self.cfg.gemma_norm else "ones"

    def _attn_defs(self, prefix) -> dict:
        cfg = self.cfg
        ps = tuple(s for s, _ in prefix)
        pa = tuple(a for _, a in prefix)
        kv_axis = "heads" if self.policy.kv_repeat == 1 else "kv_fused"
        d = {
            "norm1": PD(ps + (cfg.d_model,), pa + (None,), init=self._norm_init(), dtype=jnp.float32),
            "wq": PD(ps + (cfg.d_model, cfg.q_dim), pa + ("embed", "heads")),
            "wk": PD(ps + (cfg.d_model, cfg.kv_dim), pa + ("embed", kv_axis)),
            "wv": PD(ps + (cfg.d_model, cfg.kv_dim), pa + ("embed", kv_axis)),
            "wo": PD(ps + (cfg.q_dim, cfg.d_model), pa + ("heads", "embed_out")),
            "norm2": PD(ps + (cfg.d_model,), pa + (None,), init=self._norm_init(), dtype=jnp.float32),
        }
        if cfg.qk_norm:
            d["q_norm"] = PD(ps + (cfg.head_dim,), pa + (None,), init="ones", dtype=jnp.float32)
            d["k_norm"] = PD(ps + (cfg.head_dim,), pa + (None,), init="ones", dtype=jnp.float32)
        if cfg.post_block_norm:
            d["post1"] = PD(ps + (cfg.d_model,), pa + (None,), init=self._norm_init(), dtype=jnp.float32)
            d["post2"] = PD(ps + (cfg.d_model,), pa + (None,), init=self._norm_init(), dtype=jnp.float32)
        if cfg.moe:
            d["moe"] = moe_defs(cfg, prefix)
            if cfg.dense_residual:
                d["dense"] = mlp_defs(cfg, prefix_axes=prefix)
        else:
            d["mlp"] = mlp_defs(cfg, prefix_axes=prefix)
        return d

    def _rglru_defs(self, prefix) -> dict:
        cfg = self.cfg
        ps = tuple(s for s, _ in prefix)
        pa = tuple(a for _, a in prefix)
        return {
            "norm1": PD(ps + (cfg.d_model,), pa + (None,), init=self._norm_init(), dtype=jnp.float32),
            "rglru": rglru_mod.rglru_defs(cfg, prefix),
            "norm2": PD(ps + (cfg.d_model,), pa + (None,), init=self._norm_init(), dtype=jnp.float32),
            "mlp": mlp_defs(cfg, prefix_axes=prefix),
        }

    def _rwkv_defs(self, prefix) -> dict:
        cfg = self.cfg
        ps = tuple(s for s, _ in prefix)
        pa = tuple(a for _, a in prefix)
        f32 = jnp.float32
        return {
            "ln1_w": PD(ps + (cfg.d_model,), pa + (None,), init="ones", dtype=f32),
            "ln1_b": PD(ps + (cfg.d_model,), pa + (None,), init="zeros", dtype=f32),
            "tm": rwkv_mod.time_mix_defs(cfg, prefix),
            "ln2_w": PD(ps + (cfg.d_model,), pa + (None,), init="ones", dtype=f32),
            "ln2_b": PD(ps + (cfg.d_model,), pa + (None,), init="zeros", dtype=f32),
            "cm": rwkv_mod.channel_mix_defs(cfg, prefix),
        }

    def _block_defs(self, ch: str, prefix) -> dict:
        if ch in ("g", "l"):
            return self._attn_defs(prefix)
        if ch == "r":
            return self._rglru_defs(prefix)
        if ch == "k":
            return self._rwkv_defs(prefix)
        raise ValueError(ch)

    def _build_defs(self) -> dict:
        cfg = self.cfg
        defs: Dict[str, Any] = {}
        if cfg.frontend is None or cfg.tie_embeddings:
            defs["embed"] = PD(
                (self.vocab_pad, cfg.d_model),
                ("vocab", "embed"),
                init="embed",
                scale=cfg.d_model**-0.5,
            )
        if not cfg.tie_embeddings:
            defs["head"] = PD((cfg.d_model, self.vocab_pad), ("embed", "vocab"))
        defs["final_norm"] = PD(
            (cfg.d_model,), (None,), init=self._norm_init(), dtype=jnp.float32
        )
        prefix = ((self.n_scan, "layers"),)
        defs["blocks"] = {
            f"b{i}_{ch}": self._block_defs(ch, prefix)
            for i, ch in enumerate(self.pattern)
        }
        for i in range(self.rem):
            ch = self.pattern[i]
            defs[f"ep{i}_{ch}"] = self._block_defs(ch, ())
        return defs

    # ------------------------------------------------------------------
    # caches
    # ------------------------------------------------------------------
    def _cache_cap(self, seq_len: int, ch: str) -> int:
        cap = _round_up(seq_len + CACHE_PAD, max(self.policy.msize, 1))
        if ch == "l":
            cap = min(cap, _round_up(self.cfg.window_size, max(self.policy.msize, 1)))
        return cap

    def _block_cache_def(self, ch: str, b: int, seq_len: int, stack: int):
        cfg = self.cfg
        pre = (stack,) if stack else ()

        def sds(shape, dtype=jnp.bfloat16):
            return jax.ShapeDtypeStruct(pre + shape, dtype)

        if ch in ("g", "l"):
            cap = self._cache_cap(seq_len, ch)
            kv_eff = cfg.num_kv_heads * self.policy.kv_repeat
            return {
                "k": sds((b, cap, kv_eff, cfg.head_dim)),
                "v": sds((b, cap, kv_eff, cfg.head_dim)),
            }
        if ch == "r":
            return {
                "h": sds((b, cfg.lru_dim), jnp.float32),
                "conv": sds((b, cfg.conv1d_width - 1, cfg.lru_dim)),
            }
        if ch == "k":
            h = cfg.d_model // cfg.rwkv_head_dim
            return {
                "S": sds((b, h, cfg.rwkv_head_dim, cfg.rwkv_head_dim), jnp.float32),
                "tm_prev": sds((b, cfg.d_model)),
                "cm_prev": sds((b, cfg.d_model)),
            }
        raise ValueError(ch)

    def cache_struct(self, b: int, seq_len: int):
        out: Dict[str, Any] = {
            "blocks": {
                f"b{i}_{ch}": self._block_cache_def(ch, b, seq_len, self.n_scan)
                for i, ch in enumerate(self.pattern)
            }
        }
        for i in range(self.rem):
            ch = self.pattern[i]
            out[f"ep{i}_{ch}"] = self._block_cache_def(ch, b, seq_len, 0)
        return out

    def _cache_spec(self, sds, b: int, stacked: bool, leaf_key: str) -> P:
        b_ax = self.policy.cache_batch_axes(b) or None
        lead = (None,) if stacked else ()
        nd = len(sds.shape) - len(lead)
        if leaf_key in ("k", "v"):  # attention kv cache: seq over 'model'
            return P(*lead, b_ax, "model", None, None)
        return P(*lead, b_ax, *([None] * (nd - 1)))

    def cache_specs(self, b: int, seq_len: int):
        cs = self.cache_struct(b, seq_len)

        def spec(path, sds):
            stacked = any(
                getattr(k, "key", None) == "blocks" for k in path
            )
            leaf_key = getattr(path[-1], "key", "")
            return self._cache_spec(sds, b, stacked, leaf_key)

        return jax.tree_util.tree_map_with_path(spec, cs)

    # ------------------------------------------------------------------
    # block applications
    # ------------------------------------------------------------------
    def _tp_attention_sp(self, p, x, window, mode, b, s):
        """Megatron sequence-parallel attention block as ONE shard_map:
        all-gather(seq) -> local qkv/attention/out-proj (heads local) ->
        psum_scatter(seq). Weight grads need NO cross-shard reduction (the
        contraction over seq happens on gathered activations locally) —
        eliminates the f32 dW all-reduces SPMD otherwise emits
        (EXPERIMENTS.md §Perf-2). Returns (out seq-sharded, (k, v) full-seq
        head-sharded for prefill)."""
        cfg, pol = self.cfg, self.policy
        hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        rep = pol.kv_repeat
        kv_eff = hkv * rep
        msize = pol.msize
        scale = (cfg.query_pre_attn_scalar or cfg.head_dim) ** -0.5
        cap = cfg.attn_logit_softcap
        fsdp = pol.fsdp and pol.dsize > 1
        b_ax = pol.batch_axes(b) or None

        def local(h_loc, wq, wk, wv, wo, qn, kn):
            j = lax.axis_index("model")
            hf = lax.all_gather(h_loc, "model", axis=1, tiled=True)  # (Bl,S,d)
            bl, sl = hf.shape[0], hf.shape[1]
            if fsdp:
                wq = lax.all_gather(wq, "data", axis=0, tiled=True)
                wk = lax.all_gather(wk, "data", axis=0, tiled=True)
                wv = lax.all_gather(wv, "data", axis=0, tiled=True)
                wo = lax.all_gather(wo, "data", axis=1, tiled=True)
            if rep > 1:  # kv weights replicated over model: slice my heads
                wk = jnp.repeat(wk.reshape(cfg.d_model, hkv, hd), rep, axis=1)
                wv = jnp.repeat(wv.reshape(cfg.d_model, hkv, hd), rep, axis=1)
                kvl = kv_eff // msize
                wk = lax.dynamic_slice_in_dim(wk, j * kvl, kvl, axis=1)
                wv = lax.dynamic_slice_in_dim(wv, j * kvl, kvl, axis=1)
                wk = wk.reshape(cfg.d_model, kvl * hd)
                wv = wv.reshape(cfg.d_model, kvl * hd)
            q = (hf @ wq).reshape(bl, sl, hq // msize, hd)
            k = (hf @ wk).reshape(bl, sl, kv_eff // msize, hd)
            v = (hf @ wv).reshape(bl, sl, kv_eff // msize, hd)
            if cfg.qk_norm:
                q = rms_norm(q, qn, cfg.norm_eps, False)
                k = rms_norm(k, kn, cfg.norm_eps, False)
            positions = jnp.arange(sl)[None, :]
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
            out = attention_chunked(
                q, k, v, scale=scale, window=window,
                logit_cap=cap, chunk=cfg.attn_chunk,
            )
            partial = out.reshape(bl, sl, (hq // msize) * hd) @ wo
            out_loc = lax.psum_scatter(
                partial, "model", scatter_dimension=1, tiled=True
            )
            return out_loc, k, v

        wq_spec = P("data" if fsdp else None, "model")
        kv_axis_spec = (
            P("data" if fsdp else None, "model")
            if rep == 1
            else P("data" if fsdp else None, None)
        )
        wo_spec = P("model", "data" if fsdp else None)
        norm_spec = P(None)
        fn = jax.shard_map(
            local,
            mesh=self.mesh,
            in_specs=(
                P(b_ax, "model", None), wq_spec, kv_axis_spec, kv_axis_spec,
                wo_spec, norm_spec, norm_spec,
            ),
            out_specs=(
                P(b_ax, "model", None),
                P(b_ax, None, "model", None),
                P(b_ax, None, "model", None),
            ),
            check_vma=False,
        )
        qn = p.get("q_norm", jnp.ones((hd,), jnp.float32))
        kn = p.get("k_norm", jnp.ones((hd,), jnp.float32))
        return fn(x, p["wq"], p["wk"], p["wv"], p["wo"], qn, kn)

    def _tp_mlp_sp(self, p, x, b, s):
        """Sequence-parallel MLP twin of _tp_attention_sp."""
        cfg, pol = self.cfg, self.policy
        fsdp = pol.fsdp and pol.dsize > 1
        b_ax = pol.batch_axes(b) or None
        act = None
        gated = "w_gate" in p

        def local(h_loc, wi, wg, wo):
            hf = lax.all_gather(h_loc, "model", axis=1, tiled=True)
            if fsdp:
                wi = lax.all_gather(wi, "data", axis=0, tiled=True)
                wo = lax.all_gather(wo, "data", axis=1, tiled=True)
                if gated:
                    wg = lax.all_gather(wg, "data", axis=0, tiled=True)
            from repro.models.layers import act_fn

            hmid = hf @ wi
            if gated:
                hmid = act_fn(cfg.act)(hf @ wg) * hmid
            else:
                hmid = act_fn(cfg.act)(hmid)
            partial = hmid @ wo
            return lax.psum_scatter(partial, "model", scatter_dimension=1, tiled=True)

        w_in_spec = P("data" if fsdp else None, "model")
        w_out_spec = P("model", "data" if fsdp else None)
        fn = jax.shard_map(
            local,
            mesh=self.mesh,
            in_specs=(P(b_ax, "model", None), w_in_spec, w_in_spec, w_out_spec),
            out_specs=P(b_ax, "model", None),
            check_vma=False,
        )
        wg = p.get("w_gate", p["w_in"])
        return fn(x, p["w_in"], wg, p["w_out"])

    def _attn_apply(self, p, x, ch, cache, pos, mode):
        cfg, pol = self.cfg, self.policy
        window = cfg.window_size if ch == "l" else None
        b = x.shape[0]
        use_sp = (
            pol.profile == "tp" and pol.msize > 1 and mode != "decode"
        )
        h = rms_norm(x, p["norm1"], cfg.norm_eps, cfg.gemma_norm)
        hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        rep = pol.kv_repeat
        kv_eff = hkv * rep
        s = x.shape[1]
        scale = (cfg.query_pre_attn_scalar or cfg.head_dim) ** -0.5
        cap = cfg.attn_logit_softcap
        if use_sp:
            out, k_full, v_full = self._tp_attention_sp(p, h, window, mode, b, s)
            new_cache = None
            if mode == "prefill":
                capn = self._cache_cap(s, ch)
                if ch == "l" and capn <= s:
                    k_c, v_c = k_full[:, -capn:], v_full[:, -capn:]
                else:
                    k_c = jnp.zeros((b, capn, kv_eff, hd), k_full.dtype)
                    k_c = lax.dynamic_update_slice(k_c, k_full, (0, 0, 0, 0))
                    v_c = jnp.zeros((b, capn, kv_eff, hd), v_full.dtype)
                    v_c = lax.dynamic_update_slice(v_c, v_full, (0, 0, 0, 0))
                sp = P(pol.cache_batch_axes(b) or None, "model", None, None)
                new_cache = {"k": pol.constrain(k_c, sp), "v": pol.constrain(v_c, sp)}
            x = x + (
                rms_norm(out, p["post1"], cfg.norm_eps, cfg.gemma_norm)
                if cfg.post_block_norm
                else out
            )
            h2 = rms_norm(x, p["norm2"], cfg.norm_eps, cfg.gemma_norm)
            aux = aux_zero()
            if cfg.moe:
                ffn_out, aux = self._ffn_moe(p, h2, mode)
            else:
                ffn_out = self._tp_mlp_sp(p["mlp"], h2, b, s)
            if cfg.post_block_norm:
                ffn_out = rms_norm(ffn_out, p["post2"], cfg.norm_eps, cfg.gemma_norm)
            return x + ffn_out, new_cache, aux

        wk, wv = p["wk"], p["wv"]
        if rep > 1:
            wk = jnp.repeat(wk.reshape(cfg.d_model, hkv, hd), rep, axis=1)
            wv = jnp.repeat(wv.reshape(cfg.d_model, hkv, hd), rep, axis=1)
            wk = pol.constrain(wk.reshape(cfg.d_model, hkv * rep * hd), P(None, "model"))
            wv = pol.constrain(wv.reshape(cfg.d_model, hkv * rep * hd), P(None, "model"))
        q = (h @ p["wq"]).reshape(b, s, hq, hd)
        k = (h @ wk).reshape(b, s, kv_eff, hd)
        v = (h @ wv).reshape(b, s, kv_eff, hd)
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"], cfg.norm_eps, False)
            k = rms_norm(k, p["k_norm"], cfg.norm_eps, False)

        if mode == "decode":
            positions = jnp.full((b, 1), pos)
            q = rope(q, positions, cfg.rope_theta)[:, 0]
            k = rope(k, positions, cfg.rope_theta)[:, 0]
            v = v[:, 0]
            b_ax = pol.cache_batch_axes(b) or None
            qspec = P(b_ax, None, None)
            cspec = P(b_ax, "model", None, None)
            rolling = ch == "l"
            fn = jax.shard_map(
                lambda q_, kc_, vc_, nk_, nv_, p_: decode_attention_sharded(
                    q_, kc_, vc_, nk_, nv_, p_,
                    axis_name="model",
                    scale=scale,
                    window=window,
                    rolling=rolling,
                    logit_cap=cap,
                ),
                mesh=self.mesh,
                in_specs=(qspec, cspec, cspec, qspec, qspec, P()),
                out_specs=(qspec, cspec, cspec),
                check_vma=False,
            )
            out, k_c, v_c = fn(q, cache["k"], cache["v"], k, v, pos)
            out = out[:, None]  # (B,1,Hq,D)
            new_cache = {"k": k_c, "v": v_c}
        else:
            positions = jnp.arange(s)[None, :]
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
            if pol.profile == "cp" and pol.msize > 1:
                b_ax = pol.batch_axes(b) or None
                spec = P(b_ax, "model", None, None)
                # gathered-KV context parallelism for moderate S; the ring
                # schedule (xDFS channel pipeline) is kept for S > ~64k
                use_ring = s > 65536
                inner = (
                    (lambda q_, k_, v_: ring_attention(
                        q_, k_, v_, axis_name="model", scale=scale, logit_cap=cap))
                    if use_ring
                    else (lambda q_, k_, v_: gathered_kv_attention(
                        q_, k_, v_, axis_name="model", scale=scale, logit_cap=cap,
                        chunk=min(cfg.attn_chunk, 128)))
                )
                fn = jax.shard_map(
                    inner,
                    mesh=self.mesh,
                    in_specs=(spec, spec, spec),
                    out_specs=spec,
                    check_vma=False,
                )
                out = fn(q, k, v)
            else:
                out = attention_chunked(
                    q, k, v, scale=scale, window=window,
                    logit_cap=cap, chunk=cfg.attn_chunk,
                )
            new_cache = None
            if mode == "prefill":
                capn = self._cache_cap(s, ch)
                if ch == "l" and capn <= s:
                    # rolling window: slots (kpos % W) == arange(W) since W | S
                    k_c, v_c = k[:, -capn:], v[:, -capn:]
                else:
                    k_c = jnp.zeros((b, capn, kv_eff, hd), k.dtype)
                    k_c = lax.dynamic_update_slice(k_c, k, (0, 0, 0, 0))
                    v_c = jnp.zeros((b, capn, kv_eff, hd), v.dtype)
                    v_c = lax.dynamic_update_slice(v_c, v, (0, 0, 0, 0))
                sp = P(pol.cache_batch_axes(b) or None, "model", None, None)
                new_cache = {"k": pol.constrain(k_c, sp), "v": pol.constrain(v_c, sp)}

        out = out.reshape(b, out.shape[1], hq * hd) @ p["wo"]
        if cfg.post_block_norm:
            out = rms_norm(out, p["post1"], cfg.norm_eps, cfg.gemma_norm)
        x = x + out

        h2 = rms_norm(x, p["norm2"], cfg.norm_eps, cfg.gemma_norm)
        aux = aux_zero()
        if cfg.moe:
            ffn_out, aux = self._ffn_moe(p, h2, mode)
        else:
            ffn_out = mlp_apply(p["mlp"], h2, cfg)
        if cfg.post_block_norm:
            ffn_out = rms_norm(ffn_out, p["post2"], cfg.norm_eps, cfg.gemma_norm)
        return x + ffn_out, new_cache, aux

    def _ffn_moe(self, p, h2, mode):
        """MoE FFN with shard-major token grouping: (B,S,d) -> (bsh, B/bsh,
        ssh, S/ssh, d) -> (bsh*ssh, ., d) so MoE groups align with the
        activation sharding (no reshuffle before routing)."""
        cfg, pol = self.cfg, self.policy
        from repro.runtime.shard import axis_size

        bb, ss = h2.shape[0], h2.shape[1]
        bsh = 1
        for a_name in pol.batch_axes(bb):
            bsh *= axis_size(pol.mesh, a_name)
        ssh = 1
        for a_name in pol.act_seq_axes():
            ssh *= axis_size(pol.mesh, a_name)
        hg = h2.reshape(bsh, bb // bsh, ssh, ss // ssh, cfg.d_model)
        hg = hg.transpose(0, 2, 1, 3, 4)
        tokens = hg.reshape(-1, cfg.d_model)
        n_tok = tokens.shape[0]
        g = pol.moe_group_count(n_tok, bb)
        ng = n_tok // g
        if mode == "decode":
            capc = ng * cfg.top_k  # zero-drop
        else:
            # serving prefill must rarely drop; training tolerates cf drops
            cf = max(cfg.capacity_factor, 2.0) if mode == "prefill" else cfg.capacity_factor
            capc = max(1, math.ceil(ng * cfg.top_k / cfg.num_experts * cf))
        ffn_out, mm = moe_apply(
            p["moe"], tokens, cfg, group=ng, capacity=capc,
            policy=pol, batch=bb,
        )
        # inverse shard-major grouping
        ffn_out = (
            ffn_out.reshape(bsh, ssh, bb // bsh, ss // ssh, cfg.d_model)
            .transpose(0, 2, 1, 3, 4)
            .reshape(h2.shape)
        )
        aux = (mm.aux_loss, mm.z_loss, mm.drop_frac)
        if cfg.dense_residual:
            ffn_out = ffn_out + mlp_apply(p["dense"], h2, cfg)
        return ffn_out, aux

    def _rglru_block_apply(self, p, x, cache, mode):
        cfg = self.cfg
        h = rms_norm(x, p["norm1"], cfg.norm_eps, cfg.gemma_norm)
        state = None if mode == "train" and cache is None else cache
        out, new_state = rglru_mod.rglru_apply(p["rglru"], h, cfg, state)
        x = x + out
        h2 = rms_norm(x, p["norm2"], cfg.norm_eps, cfg.gemma_norm)
        x = x + mlp_apply(p["mlp"], h2, cfg)
        return x, (new_state if mode != "train" else None), aux_zero()

    def _rwkv_block_apply(self, p, x, cache, mode):
        cfg = self.cfg
        tm_state = None
        cm_state = None
        if cache is not None:
            tm_state = {"S": cache["S"], "prev": cache["tm_prev"]}
            cm_state = {"prev": cache["cm_prev"]}
        h = rwkv_mod._ln(x, p["ln1_w"], p["ln1_b"])
        out, tm_new = rwkv_mod.time_mix_apply(p["tm"], h, cfg, tm_state)
        x = x + out
        h2 = rwkv_mod._ln(x, p["ln2_w"], p["ln2_b"])
        out2, cm_new = rwkv_mod.channel_mix_apply(p["cm"], h2, cfg, cm_state)
        x = x + out2
        new_cache = None
        if mode != "train":
            new_cache = {
                "S": tm_new["S"],
                "tm_prev": tm_new["prev"],
                "cm_prev": cm_new["prev"],
            }
        return x, new_cache, aux_zero()

    def _apply_block(self, ch, p, x, cache, pos, mode):
        if ch in ("g", "l"):
            return self._attn_apply(p, x, ch, cache, pos, mode)
        if ch == "r":
            return self._rglru_block_apply(p, x, cache, mode)
        if ch == "k":
            return self._rwkv_block_apply(p, x, cache, mode)
        raise ValueError(ch)

    # ------------------------------------------------------------------
    # forward
    # ------------------------------------------------------------------
    def _remat(self, fn):
        if self.kind != "train" or self.cfg.remat_policy == "full":
            return fn
        if self.cfg.remat_policy == "dots":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            )
        return jax.checkpoint(fn)

    def backbone(self, params, x, caches=None, pos=None, mode="train"):
        """x: (B, S, d). Returns (hidden, new_caches, aux)."""
        pol = self.policy
        b = x.shape[0]
        x = pol.constrain(x, pol.hidden_spec(b))
        aux0 = aux_zero()

        def body(carry, xs):
            xc, aux = carry
            gp, gcache = xs
            new_caches = {}
            for i, ch in enumerate(self.pattern):
                key = f"b{i}_{ch}"
                xc, nc, a = self._apply_block(
                    ch, gp[key], xc, None if gcache is None else gcache[key], pos, mode
                )
                if nc is not None:
                    new_caches[key] = nc
                aux = tuple(u + v for u, v in zip(aux, a))
            xc = pol.constrain(xc, pol.hidden_spec(b))
            return (xc, aux), (new_caches or None)

        body = self._remat(body)
        stack_caches = None if caches is None else caches["blocks"]
        if mode == "train":
            xs = (params["blocks"], None)
        else:
            xs = (params["blocks"], stack_caches)
        (x, aux), ys = lax.scan(body, (x, aux0), xs)
        new_caches = {"blocks": ys} if mode != "train" else None

        for i in range(self.rem):
            ch = self.pattern[i]
            key = f"ep{i}_{ch}"
            c_in = None if caches is None else caches[key]
            x, nc, a = self._apply_block(ch, params[key], x, c_in, pos, mode)
            if mode != "train" and new_caches is not None:
                new_caches[key] = nc
            aux = tuple(u + v for u, v in zip(aux, a))
        x = rms_norm(x, params["final_norm"], self.cfg.norm_eps, self.cfg.gemma_norm)
        return x, new_caches, aux

    # ------------------------------------------------------------------
    def embed_inputs(self, params, inputs):
        cfg = self.cfg
        if cfg.frontend is not None:
            return inputs.astype(jnp.bfloat16)
        e = jnp.take(params["embed"], inputs, axis=0)
        if cfg.embed_scale:
            e = e * jnp.asarray(math.sqrt(cfg.d_model), e.dtype)
        return e

    def _head_weight(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["head"]

    def _mask_pad_vocab(self, logits):
        if self.vocab_pad == self.cfg.vocab_size:
            return logits
        valid = jnp.arange(self.vocab_pad) < self.cfg.vocab_size
        return jnp.where(valid, logits, -1e30)

    def logits_fn(self, params, h):
        w = self._head_weight(params)
        logits = (h @ w).astype(jnp.float32)
        logits = softcap(logits, self.cfg.final_logit_softcap)
        return self._mask_pad_vocab(logits)

    def loss(self, params, batch):
        """batch: inputs (B,S) int32 or (B,S,d) embeds; labels (B,S) int32."""
        cfg, pol = self.cfg, self.policy
        x = self.embed_inputs(params, batch["inputs"])
        h, _, aux = self.backbone(params, x, mode="train")
        labels = batch["labels"]
        b, s = labels.shape
        # CE stage wants vocab sharding on 'model'; release the seq shard
        h = pol.constrain(h, P(pol.batch_axes(b) or None, None, None))
        from repro.models.rwkv6 import best_chunk

        chunk = best_chunk(s, cfg.ce_chunk)
        n = s // chunk
        w = self._head_weight(params)
        hc = h.reshape(b, n, chunk, cfg.d_model).transpose(1, 0, 2, 3)
        yc = labels.reshape(b, n, chunk).transpose(1, 0, 2)

        ce_spec = pol.ce_logits_spec(b)

        def ce_body(acc, xs):
            hh, yy = xs
            logits = pol.constrain((hh @ w).astype(jnp.float32), ce_spec)
            logits = softcap(logits, cfg.final_logit_softcap)
            logits = self._mask_pad_vocab(logits)
            lz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, yy[..., None], axis=-1)[..., 0]
            return acc + jnp.sum(lz - gold), None

        acc, _ = lax.scan(jax.checkpoint(ce_body), jnp.zeros((), jnp.float32), (hc, yc))
        ce = acc / (b * s)
        aux_loss, z_loss, drop = aux
        total = ce + cfg.router_aux_weight * aux_loss + cfg.router_z_weight * z_loss
        metrics = {
            "loss": total,
            "ce": ce,
            "moe_aux": aux_loss,
            "moe_z": z_loss,
            "moe_drop": drop / max(cfg.num_layers, 1),
        }
        return total, metrics

    def prefill(self, params, batch):
        x = self.embed_inputs(params, batch["inputs"])
        h, caches, _ = self.backbone(params, x, mode="prefill")
        logits = self.logits_fn(params, h[:, -1:])
        return logits, caches

    def decode_step(self, params, batch):
        """batch: inputs (B,1)|(B,1,d), caches, pos (scalar int32)."""
        x = self.embed_inputs(params, batch["inputs"])
        h, caches, _ = self.backbone(
            params, x, caches=batch["caches"], pos=batch["pos"], mode="decode"
        )
        logits = self.logits_fn(params, h)
        return logits, caches

    # ------------------------------------------------------------------
    # inputs / shardings for the launcher
    # ------------------------------------------------------------------
    def input_struct(self, shape: ShapeConfig):
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
        emb = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
        if shape.kind == "train":
            inp = emb if cfg.frontend else tok
            return {"inputs": inp, "labels": tok}
        if shape.kind == "prefill":
            return {"inputs": emb if cfg.frontend else tok}
        # decode
        one = jax.ShapeDtypeStruct(
            (b, 1, cfg.d_model) if cfg.frontend else (b, 1),
            jnp.bfloat16 if cfg.frontend else jnp.int32,
        )
        return {
            "inputs": one,
            "caches": self.cache_struct(b, s),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }

    def input_specs(self, shape: ShapeConfig):
        pol = self.policy
        b = shape.global_batch
        b_ax = pol.batch_axes(b) or None
        seq_ax = pol.seq_axes() if shape.kind != "decode" else ()
        tok_spec = P(b_ax, seq_ax or None)
        emb_spec = P(b_ax, seq_ax or None, None)
        cfg = self.cfg
        if shape.kind == "train":
            return {
                "inputs": emb_spec if cfg.frontend else tok_spec,
                "labels": tok_spec,
            }
        if shape.kind == "prefill":
            return {"inputs": emb_spec if cfg.frontend else tok_spec}
        return {
            "inputs": P(b_ax, None, None) if cfg.frontend else P(b_ax, None),
            "caches": self.cache_specs(b, shape.seq_len),
            "pos": P(),
        }

    def abstract(self):
        return abstract_params(self.defs)

    def init(self, key):
        return init_params(self.defs, key)


def build_model(cfg: ModelConfig, mesh, kind: str, plain: bool = False) -> LM:
    return LM(cfg, mesh, kind, plain=plain)
