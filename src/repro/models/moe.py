"""Mixture-of-Experts layer: top-k token-choice routing, gather/scatter
dispatch (NO one-hot dispatch einsums — those would double compiled FLOPs and
poison the roofline), and an EXPLICIT shard_map expert stage.

The expert-parallel transition is written with jax.lax collectives instead of
relying on SPMD to infer it (the inferred path involuntarily rematerializes
~70 GiB buffers in the backward pass for cross-axis transposes — measured on
arctic-480b; see EXPERIMENTS.md §Dry-run):

  * tokens sequence-sharded over 'model' (cp profile): all_to_all over
    'model' splits the expert dim and concatenates groups — the GShard
    transition, explicitly.
  * tokens replicated over 'model' (tp profile): each model rank slices its
    own experts and the combine is a psum — row-parallel MoE.

Expert weights are EP-sharded over 'model' with their fan-in dim ZeRO-sharded
over 'data' (all-gathered on entry; the backward re-scatters — standard ZeRO-3).

Routing/bookkeeping (cumsum capacity assignment) stays group-local so it
never crosses shards. Capacity:
  * train: C = ceil(group * top_k / E * capacity_factor)   (may drop)
  * prefill: same with capacity_factor >= 2 (rare drops)
  * decode: C = group * top_k                              (zero-drop)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models.layers import PD, act_fn


class MoEMetrics(NamedTuple):
    aux_loss: jax.Array  # load-balancing loss
    z_loss: jax.Array  # router z-loss
    drop_frac: jax.Array  # fraction of (token, k) assignments dropped


def moe_defs(cfg, prefix_axes=()) -> dict:
    pre_s = tuple(s for s, _ in prefix_axes)
    pre_a = tuple(a for _, a in prefix_axes)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.moe_dff
    return {
        "router": PD(pre_s + (d, e), pre_a + ("embed", None), dtype=jnp.float32),
        "w_in": PD(pre_s + (e, d, f), pre_a + ("experts", "embed", "ff")),
        "w_gate": PD(pre_s + (e, d, f), pre_a + ("experts", "embed", "ff")),
        "w_out": PD(pre_s + (e, f, d), pre_a + ("experts", "ff", "embed_out")),
    }


def _expert_ffn_shard_map(policy, cfg, expert_in, w_in, w_gate, w_out, tok_axes):
    """(G, E, C, d) -> (G, E, C, d) expert FFN with explicit collectives."""
    e = cfg.num_experts
    msize = policy.msize
    use_a2a = "model" in tok_axes
    act = act_fn(cfg.act)
    gspec = P(tok_axes or None, None, None, None)
    wspec = policy.expert_wspec()
    fsdp = policy.fsdp

    compress = getattr(cfg, "moe_a2a_compress", False)

    def a2a(t, split_axis, concat_axis):
        """Expert-parallel all-to-all, optionally through the ZxDFS int8
        channel (quantize in VMEM -> int8 on the wire -> dequant): halves
        the a2a wire bytes (EXPERIMENTS.md §Perf-3)."""
        if not compress:
            return lax.all_to_all(t, "model", split_axis=split_axis,
                                  concat_axis=concat_axis, tiled=True)
        from repro.core.compress import Quantized, dequantize_int8, quantize_int8

        amax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1, keepdims=True)
        scale = jnp.maximum(amax, 1e-30) / 127.0
        q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
        q = lax.all_to_all(q, "model", split_axis=split_axis,
                           concat_axis=concat_axis, tiled=True)
        scale = lax.all_to_all(scale, "model", split_axis=split_axis,
                               concat_axis=concat_axis, tiled=True)
        return (q.astype(jnp.float32) * scale).astype(t.dtype)

    def local(xi, wi, wg, wo):
        # xi: (g_loc, E, C, d); wi/wg: (E_loc, d_loc, f); wo: (E_loc, f_loc, d)
        if msize > 1:
            if use_a2a:
                xi = a2a(xi, 1, 0)
            else:
                j = lax.axis_index("model")
                xi = lax.dynamic_slice_in_dim(xi, j * (e // msize), e // msize, axis=1)
        if fsdp and policy.dsize > 1:
            wi = lax.all_gather(wi, "data", axis=1, tiled=True)
            wg = lax.all_gather(wg, "data", axis=1, tiled=True)
            wo = lax.all_gather(wo, "data", axis=1, tiled=True)
        h = jnp.einsum("gecd,edf->gecf", xi, wi)
        h = act(jnp.einsum("gecd,edf->gecf", xi, wg)) * h
        out = jnp.einsum("gecf,efd->gecd", h, wo)
        if msize > 1:
            if use_a2a:
                out = a2a(out, 0, 1)
            else:
                buf = jnp.zeros(xi.shape[:1] + (e,) + xi.shape[2:], out.dtype)
                j = lax.axis_index("model")
                buf = lax.dynamic_update_slice_in_dim(buf, out, j * (e // msize), axis=1)
                out = lax.psum(buf, "model")
        return out

    fn = jax.shard_map(
        local,
        mesh=policy.mesh,
        in_specs=(gspec, wspec, wspec, wspec),
        out_specs=gspec,
        check_vma=False,
    )
    return fn(expert_in, w_in, w_gate, w_out)


def moe_apply(params, x, cfg, *, group: int, capacity: int, policy, batch: int):
    """x: (T, d) flat tokens in SHARD-MAJOR order, T divisible by group.

    Returns (T, d), MoEMetrics.
    """
    t, d = x.shape
    e, k, c = cfg.num_experts, cfg.top_k, capacity
    g = t // group
    tok_axes = policy.moe_token_axes(batch)
    con = lambda a, spec: policy.constrain(a, spec)

    xg = con(x.reshape(g, group, d), P(tok_axes or None, None, None))

    # ---- routing (f32 accumulation; no f32 copy of the activations) ---------
    logits = jnp.einsum(
        "gnd,de->gne",
        xg,
        params["router"].astype(xg.dtype),
        preferred_element_type=jnp.float32,
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)  # (g, n, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux losses (Switch/GShard load-balance + z-loss)
    me = probs.mean(axis=(0, 1))  # (e,)
    ce_frac = jnp.zeros((e,)).at[expert_ids.reshape(-1)].add(1.0) / (g * group * k)
    aux = e * jnp.sum(me * ce_frac)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # ---- capacity assignment (group-local cumsum over flattened (n,k)) ------
    flat_e = expert_ids.reshape(g, group * k)  # (g, nk)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (g, nk, e)
    pos = jnp.cumsum(onehot, axis=1) - 1
    pos = jnp.take_along_axis(pos, flat_e[..., None], axis=-1)[..., 0]  # (g, nk)
    keep = pos < c
    drop_frac = 1.0 - keep.mean()

    slot = flat_e * c + jnp.where(keep, pos, 0)  # (g, nk) in [0, e*c)
    token_of = jnp.arange(group * k, dtype=jnp.int32) // k  # (nk,)

    # inverse map: which token (if any) fills each (expert, cap) slot.
    # dropped assignments scatter to index e*c which mode="drop" discards;
    # kept slots are unique by construction (pos is a per-expert running count).
    slot_to_tok = jnp.full((g, e * c), group, jnp.int32)  # 'group' = empty sentinel
    slot_to_tok = slot_to_tok.at[
        jnp.arange(g)[:, None], jnp.where(keep, slot, e * c)
    ].set(token_of[None, :].repeat(g, 0), mode="drop")

    valid = slot_to_tok < group
    gather_idx = jnp.minimum(slot_to_tok, group - 1)
    expert_in = jnp.take_along_axis(xg, gather_idx[..., None], axis=1)  # (g, e*c, d)
    expert_in = jnp.where(valid[..., None], expert_in, 0).reshape(g, e, c, d)
    expert_in = con(expert_in, P(tok_axes or None, None, None, None))

    # ---- expert FFN (explicit shard_map stage) -------------------------------
    eo = _expert_ffn_shard_map(
        policy, cfg, expert_in, params["w_in"], params["w_gate"], params["w_out"],
        tok_axes,
    ).reshape(g, e * c, d)

    # ---- combine back to tokens ---------------------------------------------
    picked = jnp.take_along_axis(eo, slot[..., None], axis=1)  # (g, nk, d)
    picked = jnp.where(keep[..., None], picked, 0)
    w = gate_vals.reshape(g, group * k, 1).astype(picked.dtype)
    out = (picked * w).reshape(g, group, k, d).sum(axis=2)

    metrics = MoEMetrics(aux.astype(jnp.float32), z.astype(jnp.float32), drop_frac)
    return out.reshape(t, d).astype(x.dtype), metrics
