"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Recurrence (diagonal, per-channel, f32):
  r_t = sigmoid(W_a x_t)            # recurrence gate
  i_t = sigmoid(W_x x_t)            # input gate
  log a_t = -c * softplus(L) * r_t  # c = 8
  h_t = a_t o h_{t-1} + sqrt(1 - a_t^2) o (i_t o x_t)

Block:  y = W_out( GeLU(W_gate x) o RGLRU(conv1d_4(W_in x)) )
Train/prefill uses a chunked associative scan (the Pallas kernel
``kernels/rglru_scan`` is the TPU-target twin of the inner scan).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import PD

C_FACTOR = 8.0
CHUNK = 256


def rglru_defs(cfg, prefix=()) -> dict:
    d, lru, cw = cfg.d_model, cfg.lru_dim, cfg.conv1d_width
    ps = tuple(s for s, _ in prefix)
    pa = tuple(a for _, a in prefix)
    return {
        "w_in": PD(ps + (d, lru), pa + ("embed", "lru")),
        "w_gate": PD(ps + (d, lru), pa + ("embed", "lru")),
        "w_out": PD(ps + (lru, d), pa + ("lru", "embed_out")),
        "conv_w": PD(ps + (cw, lru), pa + (None, "lru"), scale=0.3),
        "conv_b": PD(ps + (lru,), pa + ("lru",), init="zeros"),
        "w_a": PD(ps + (lru, lru), pa + ("lru", "lru_out")),
        "b_a": PD(ps + (lru,), pa + ("lru",), init="zeros", dtype=jnp.float32),
        "w_x": PD(ps + (lru, lru), pa + ("lru", "lru_out")),
        "b_x": PD(ps + (lru,), pa + ("lru",), init="zeros", dtype=jnp.float32),
        # Lambda init so that a^c spans ~[0.9, 0.999] at r=1 (Griffin app. A)
        "lam": PD(ps + (lru,), pa + ("lru",), init="ones", dtype=jnp.float32),
    }


def _causal_conv1d(x, w, b, state):
    """x: (B,S,C); w: (cw,C); state: (B,cw-1,C) trailing inputs of prev segment."""
    cw = w.shape[0]
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)  # (B, S+cw-1, C)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(cw)) + b
    return out.astype(x.dtype), xp[:, -(cw - 1) :, :]


def linear_scan_chunked(a, bx, h0, chunk=CHUNK):
    """Diagonal linear recurrence h_t = a_t*h_{t-1} + bx_t, scanned over chunks.

    a, bx: (B,S,C) f32; h0: (B,C) f32. Returns (h_all (B,S,C), h_last).
    """
    from repro.models.rwkv6 import best_chunk

    b, s, c = a.shape
    chunk = best_chunk(s, chunk)
    n = s // chunk
    ac = a.reshape(b, n, chunk, c).transpose(1, 0, 2, 3)
    bc = bx.reshape(b, n, chunk, c).transpose(1, 0, 2, 3)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    def body(h, xs):
        ab, bb = xs  # (B,L,C)
        acc_a, acc_b = lax.associative_scan(combine, (ab, bb), axis=1)
        hs = acc_a * h[:, None, :] + acc_b
        return hs[:, -1, :], hs

    h_last, hc = lax.scan(body, h0, (ac, bc))
    return hc.transpose(1, 0, 2, 3).reshape(b, s, c), h_last


def rglru_apply(p, x, cfg, state):
    """x: (B,S,d). state: dict(h=(B,lru) f32, conv=(B,cw-1,lru)) or None.

    Returns (out (B,S,d), new_state).
    """
    b, s, d = x.shape
    lru, cw = cfg.lru_dim, cfg.conv1d_width
    if state is None:
        h0 = jnp.zeros((b, lru), jnp.float32)
        conv_state = jnp.zeros((b, cw - 1, lru), x.dtype)
    else:
        h0, conv_state = state["h"], state["conv"]

    gate = jax.nn.gelu(x @ p["w_gate"])
    u = x @ p["w_in"]
    u, conv_state = _causal_conv1d(u, p["conv_w"], p["conv_b"], conv_state)

    u32 = u.astype(jnp.float32)
    r = jax.nn.sigmoid(u32 @ p["w_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(u32 @ p["w_x"].astype(jnp.float32) + p["b_x"])
    log_a = -C_FACTOR * jax.nn.softplus(p["lam"]) * r  # (B,S,lru) <= 0
    a = jnp.exp(log_a)
    # sqrt(1-a^2) with a->1 safety
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    bx = beta * (i * u32)
    h, h_last = linear_scan_chunked(a, bx, h0)
    out = (gate * h.astype(x.dtype)) @ p["w_out"]
    return out, {"h": h_last, "conv": conv_state}
