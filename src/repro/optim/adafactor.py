"""Adafactor (factored second moment, no first moment) — used for arctic-480b
where AdamW fp32 state would exceed single-pod HBM (see EXPERIMENTS.md)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class FactoredSlot(NamedTuple):
    row: jax.Array  # reduced over last dim
    col: jax.Array  # reduced over second-to-last dim
    full: jax.Array  # only for <2D params (shape (1,) dummy otherwise)


class AdafactorState(NamedTuple):
    step: jax.Array
    slots: dict


def _factored(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1


class Adafactor(NamedTuple):
    lr: float = 1e-3
    decay: float = 0.8  # beta2 = 1 - step^-decay
    eps: float = 1e-30
    clip_threshold: float = 1.0

    def init(self, params):
        def slot(p):
            if _factored(p):
                return FactoredSlot(
                    row=jnp.zeros(p.shape[:-1], jnp.float32),
                    col=jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                    full=jnp.zeros((1,), jnp.float32),
                )
            return FactoredSlot(
                row=jnp.zeros((1,), jnp.float32),
                col=jnp.zeros((1,), jnp.float32),
                full=jnp.zeros(p.shape, jnp.float32),
            )

        return AdafactorState(
            step=jnp.zeros((), jnp.int32),
            slots=jax.tree.map(slot, params),
        )

    def state_specs(self, param_specs, param_defs):
        """Derive factored-state specs from param specs (drop reduced dim)."""
        from repro.models.layers import is_pd

        specs, treedef = jax.tree.flatten(param_specs)
        defs = treedef.flatten_up_to(jax.tree.map(lambda pd: pd, param_defs, is_leaf=is_pd))

        def slot_spec(spec, pd):
            shape = pd.shape
            sp = tuple(spec) + (None,) * (len(shape) - len(spec))
            if len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1:
                return FactoredSlot(
                    row=P(*sp[:-1]),
                    col=P(*(sp[:-2] + (sp[-1],))),
                    full=P(None),
                )
            return FactoredSlot(row=P(None), col=P(None), full=spec)

        slots = treedef.unflatten([slot_spec(s, d) for s, d in zip(specs, defs)])
        return AdafactorState(step=P(), slots=slots)

    def update(self, grads, state, params):
        step = state.step + 1
        beta2 = 1.0 - step.astype(jnp.float32) ** (-self.decay)

        def upd(g, s, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + self.eps
            if _factored(p):
                row = beta2 * s.row + (1 - beta2) * g2.mean(axis=-1)
                col = beta2 * s.col + (1 - beta2) * g2.mean(axis=-2)
                row_mean = row.mean(axis=-1, keepdims=True)
                v = (row / jnp.maximum(row_mean, self.eps))[..., None] * col[..., None, :]
                new_slot = FactoredSlot(row=row, col=col, full=s.full)
            else:
                v = beta2 * s.full + (1 - beta2) * g2
                new_slot = FactoredSlot(row=s.row, col=s.col, full=v)
            u = g32 / jnp.sqrt(jnp.maximum(v, self.eps))
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / self.clip_threshold)
            return (-self.lr * u).astype(p.dtype), new_slot

        g_leaves, treedef = jax.tree.flatten(grads)
        p_leaves = treedef.flatten_up_to(params)
        s_leaves = treedef.flatten_up_to(state.slots)
        out = [upd(g, s, p) for g, s, p in zip(g_leaves, s_leaves, p_leaves)]
        updates = treedef.unflatten([o[0] for o in out])
        slots = treedef.unflatten([o[1] for o in out])
        return updates, AdafactorState(step=step, slots=slots)
