from repro.optim.adafactor import Adafactor, AdafactorState
from repro.optim.adamw import AdamW, AdamWState
from repro.optim.clip import clip_by_global_norm, global_norm


def make_optimizer(cfg, lr: float = 3e-4):
    if cfg.optimizer == "adafactor":
        return Adafactor(lr=lr)
    return AdamW(lr=lr)
