"""AdamW with decoupled weight decay; optimizer state sharded like params."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


class AdamW(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: str = "float32"

    def init(self, params):
        dt = jnp.dtype(self.state_dtype)
        zeros = lambda p: jnp.zeros(p.shape, dt)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def state_specs(self, param_specs):
        """Optimizer-state PartitionSpecs mirror the parameter specs."""
        from jax.sharding import PartitionSpec as P

        return AdamWState(step=P(), m=param_specs, v=param_specs)

    def update(self, grads, state, params):
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(m.dtype)
            m_new = b1 * m + (1 - b1) * g32
            v_new = b2 * v + (1 - b2) * jnp.square(g32)
            mh = m_new / c1
            vh = v_new / c2
            delta = mh / (jnp.sqrt(vh) + self.eps) + self.weight_decay * p.astype(m.dtype)
            return (-self.lr * delta).astype(p.dtype), m_new, v_new

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        updates = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return updates, AdamWState(step=step, m=m, v=v)
