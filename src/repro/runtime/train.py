"""Training step factory: pjit'd step with sharded params/opt-state, global
grad clipping, and the optional xDFS compressed-gradient channel (ZxDFS) for
the data-parallel all-reduce.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.optim import Adafactor, AdamW, clip_by_global_norm, make_optimizer


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array


def init_state(model, key, optimizer) -> TrainState:
    params = model.init(key)
    return TrainState(
        params=params,
        opt_state=optimizer.init(params),
        step=jnp.zeros((), jnp.int32),
    )


def state_shardings(model, optimizer):
    """NamedShardings for the full TrainState (params + optimizer slots)."""
    pol = model.policy
    pspecs = pol.param_specs(model.defs)
    if isinstance(optimizer, Adafactor):
        ospecs = optimizer.state_specs(pspecs, model.defs)
    else:
        ospecs = optimizer.state_specs(pspecs)
    mk = lambda spec: NamedSharding(pol.mesh, spec)
    return TrainState(
        params=jax.tree.map(mk, pspecs, is_leaf=lambda x: isinstance(x, P)),
        opt_state=jax.tree.map(mk, ospecs, is_leaf=lambda x: isinstance(x, P)),
        step=mk(P()),
    )


def make_train_step(
    model,
    optimizer,
    *,
    max_grad_norm: float = 1.0,
    grad_channel=None,  # optional xDFS compressed all-reduce (core.channel)
    microbatches: int = 0,  # 0 -> cfg.microbatches
):
    """Returns train_step(state, batch) -> (state, metrics).

    microbatches > 1 scans gradient accumulation over batch slices (halves+
    activation memory; the batch slice must stay divisible by the DP axes,
    so this suits tp/cp profiles — see EXPERIMENTS.md §Perf-3)."""
    k = microbatches or getattr(model.cfg, "microbatches", 1)

    def grads_of(params, batch):
        (_, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch
        )
        return grads, metrics

    def train_step(state: TrainState, batch):
        if k > 1:
            split = lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def body(gacc, mb):
                g, metrics = grads_of(state.params, mb)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), gacc, g
                )
                return gacc, metrics

            # accumulate in param dtype: an f32 accumulator would double the
            # resident grad bytes on ZeRO'd 480B params; k<=4 keeps bf16
            # accumulation error ~1 ulp
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, p.dtype), state.params
            )
            grads, ms = jax.lax.scan(body, zeros, mbs)
            grads = jax.tree.map(lambda g: g / k, grads)
            metrics = jax.tree.map(lambda m: m.mean(), ms)
        else:
            grads, metrics = grads_of(state.params, batch)
        if grad_channel is not None:
            grads = grad_channel(grads)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        updates, opt_state = optimizer.update(grads, state.opt_state, state.params)
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype), state.params, updates)
        metrics = dict(metrics, grad_norm=gnorm)
        return TrainState(params, opt_state, state.step + 1), metrics

    return train_step


def make_dp_xdfs_train_step(model, optimizer, *, compress: bool = False,
                            max_grad_norm: float = 1.0):
    """Whole-step shard_map data-parallel training with the xDFS gradient
    channel: parameters replicated, per-shard grads pushed through the
    chunked bidirectional ring all-reduce (optionally ZxDFS int8-compressed
    — halves ICI bytes; see EXPERIMENTS.md §Perf). Requires a dp-profile
    arch with replicated params (e.g. smollm-135m with fsdp=False)."""
    from repro.core.channel import xdfs_psum_tree

    mesh = model.policy.mesh
    axes = tuple(mesh.axis_names)
    flat_ax = axes  # grads reduced over every mesh axis (pure DP)
    n_total = 1
    for a in axes:
        n_total *= mesh.shape[a]

    def local_step(state: TrainState, batch):
        def loss_fn(params):
            return model.loss(params, batch)

        (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(state.params)
        # FTSM upload: push gradients through the ring channel, axis by axis
        for ax in flat_ax:
            grads = xdfs_psum_tree(grads, ax, compress=compress)
        grads = jax.tree.map(lambda g: g / n_total, grads)
        metrics = {k: jax.lax.pmean(v, flat_ax) for k, v in metrics.items()}
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        updates, opt_state = optimizer.update(grads, state.opt_state, state.params)
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype), state.params, updates)
        metrics = dict(metrics, grad_norm=gnorm)
        return TrainState(params, opt_state, state.step + 1), metrics

    from jax.sharding import PartitionSpec as P

    rep = P()
    batch_spec = {
        "inputs": P(axes),
        "labels": P(axes),
    }
    # params/opt replicated; batch sharded over all axes on dim 0
    step = jax.shard_map(
        local_step,
        mesh=mesh,
        in_specs=(
            TrainState(params=rep, opt_state=rep, step=rep),
            batch_spec,
        ),
        out_specs=(TrainState(params=rep, opt_state=rep, step=rep), rep),
        check_vma=False,
    )
    return jax.jit(step, donate_argnums=(0,))


def jit_train_step(model, optimizer, shape, **kw):
    """pjit the step with explicit in/out shardings (for the dry-run)."""
    step = make_train_step(model, optimizer, **kw)
    ss = state_shardings(model, optimizer)
    mesh = model.policy.mesh
    in_sh = jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        model.input_specs(shape),
        is_leaf=lambda x: isinstance(x, P),
    )
    return jax.jit(
        step,
        in_shardings=(ss, in_sh),
        out_shardings=(ss, None),
        donate_argnums=(0,),
    )
