"""Fault-tolerance supervisor: CFSM-driven, per the xDFS exception-header
design — errors are first-class protocol events, not crashes.

The supervisor reuses core.fsm.Machine for its lifecycle and implements the
cluster-scale behaviors the system prompt requires, scaled to what is
observable in-process:

  * heartbeats: every logical worker (data shard) reports per-step; a
    missing heartbeat past ``heartbeat_timeout`` is a fault.
  * fault -> RESTORING: reload the latest complete checkpoint (xdfs_ckpt
    walks back past corrupt steps), rebuild the step fn, resume the data
    stream at the checkpointed step (bit-exact: data is a pure fn of step).
  * straggler mitigation: steps slower than ``straggler_factor`` x the
    rolling median are flagged; the driver's hook can re-dispatch (in a
    multi-controller deployment this maps to sending the slow host's xDFS
    channels to a hot spare; here it re-executes the step, which is safe
    because train_step is deterministic given (state, batch)).
  * elastic events delegate to runtime.elastic.
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.fsm import Machine


def supervisor_fsm() -> Machine:
    states = frozenset({
        "init", "running", "checkpointing", "restoring", "rescaling",
        "halted",
    })
    t = {
        ("init", "start"): "running",
        ("running", "ckpt_begin"): "checkpointing",
        ("checkpointing", "ckpt_done"): "running",
        ("running", "fault"): "restoring",
        ("checkpointing", "fault"): "restoring",
        ("restoring", "restored"): "running",
        ("running", "rescale"): "rescaling",
        ("rescaling", "rescaled"): "running",
        ("running", "stop"): "halted",
        ("restoring", "unrecoverable"): "halted",
    }
    return Machine("supervisor", states, "init", frozenset({"halted"}), t)


@dataclass
class StepRecord:
    step: int
    wall_s: float
    straggler: bool


@dataclass
class Supervisor:
    heartbeat_timeout: float = 30.0
    straggler_factor: float = 3.0
    window: int = 50
    fsm: Machine = field(default_factory=supervisor_fsm)
    _beats: Dict[str, float] = field(default_factory=dict)
    _times: List[float] = field(default_factory=list)
    history: List[StepRecord] = field(default_factory=list)
    faults: List[str] = field(default_factory=list)
    stragglers: int = 0

    def start(self):
        self.fsm.step("start")

    # ---- heartbeats -------------------------------------------------
    def heartbeat(self, worker: str, now: Optional[float] = None):
        self._beats[worker] = now if now is not None else time.monotonic()

    def dead_workers(self, now: Optional[float] = None) -> List[str]:
        now = now if now is not None else time.monotonic()
        return [
            w for w, t in self._beats.items() if now - t > self.heartbeat_timeout
        ]

    # ---- per-step bookkeeping ---------------------------------------
    def record_step(self, step: int, wall_s: float) -> StepRecord:
        self._times.append(wall_s)
        if len(self._times) > self.window:
            self._times.pop(0)
        med = statistics.median(self._times)
        straggler = len(self._times) >= 5 and wall_s > self.straggler_factor * med
        if straggler:
            self.stragglers += 1
        rec = StepRecord(step, wall_s, straggler)
        self.history.append(rec)
        return rec

    # ---- fault / recovery flow ----------------------------------------
    def report_fault(self, what: str):
        self.faults.append(what)
        self.fsm.step("fault")

    def restored(self):
        self.fsm.step("restored")

    def checkpoint_scope(self):
        sup = self

        class _Scope:
            def __enter__(self):
                sup.fsm.step("ckpt_begin")

            def __exit__(self, et, ev, tb):
                if et is None:
                    sup.fsm.step("ckpt_done")
                else:
                    sup.faults.append(repr(ev))
                    sup.fsm.step("fault")
                return False

        return _Scope()
