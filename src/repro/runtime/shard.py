"""Sharding policies: logical param/activation axes -> mesh axes.

Profiles (chosen per-arch in configs, see DESIGN.md §4):
  tp : Megatron TP over 'model' + DP over ('pod','data') + FSDP over 'data'.
  cp : context parallel — seq over 'model' (ring attention), ZeRO-3 params
       over ('data','model'), experts over 'model' (EP).
  dp : pure DP over ('pod','data','model') (or what divides), FSDP over 'data'.

Decode always uses batch over ('pod','data') + sequence-sharded KV cache over
'model' (flash-decoding), independent of profile.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.layers import PD, is_pd

TENSOR_AXES = {"heads", "ff", "vocab", "lru", "lru_out"}  # tp: -> 'model'
FSDP_AXES = {"embed", "embed_out"}  # tp: -> 'data' (ZeRO)


def axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


@dataclass(frozen=True)
class Policy:
    profile: str  # tp | cp | dp
    mesh: Mesh
    kind: str  # train | prefill | decode
    fsdp: bool
    kv_repeat: int  # weight-repeat factor for GQA kv heads under TP
    # identity constraints: used when the WHOLE step runs inside shard_map
    # (the xDFS dp channel path) where with_sharding_constraint is illegal
    plain: bool = False

    # ----- mesh topology ------------------------------------------------
    @property
    def has_pod(self) -> bool:
        return "pod" in self.mesh.axis_names

    @property
    def dsize(self) -> int:
        return axis_size(self.mesh, "data")

    @property
    def msize(self) -> int:
        return axis_size(self.mesh, "model")

    @property
    def psize(self) -> int:
        return axis_size(self.mesh, "pod")

    # ----- activations ----------------------------------------------------
    def _divide(self, b: int, cand: Tuple[str, ...]) -> Tuple[str, ...]:
        axes: Tuple[str, ...] = ()
        prod = 1
        for a in cand:
            if b % (prod * axis_size(self.mesh, a)) == 0:
                axes += (a,)
                prod *= axis_size(self.mesh, a)
        return axes

    def batch_axes(self, b: int) -> Tuple[str, ...]:
        """Largest prefix-product of DP axes that divides the batch."""
        if self.kind == "decode":
            return self._divide(b, ("pod", "data") if self.has_pod else ("data",))
        if self.profile == "dp":
            if self.has_pod:
                # prefer saturating (data, model) over leaving 'model' idle:
                # with global_batch < n_chips, replicating over 'pod' wastes
                # a pod's FLOPs but keeps per-chip memory flat (noted in
                # EXPERIMENTS.md); (pod,data) with idle 'model' blows memory
                # AND compute 16x.
                best: Tuple[str, ...] = ()
                for cand in (("pod", "data", "model"), ("data", "model"), ("pod", "data"), ("data",)):
                    got = self._divide(b, cand)
                    if len(got) == len(cand):
                        return got
                    if not best:
                        best = got
                return best
            return self._divide(b, ("data", "model"))
        return self._divide(b, ("pod", "data") if self.has_pod else ("data",))

    def cache_batch_axes(self, b: int) -> Tuple[str, ...]:
        """KV-cache batch axes: never 'model' (the cache seq dim owns it)."""
        cand = ("pod", "data") if self.has_pod else ("data",)
        axes: Tuple[str, ...] = ()
        prod = 1
        for a in cand:
            if b % (prod * axis_size(self.mesh, a)) == 0:
                axes += (a,)
                prod *= axis_size(self.mesh, a)
        return axes

    def seq_axes(self) -> Tuple[str, ...]:
        if self.kind == "decode":
            return ("model",)  # KV cache sequence sharding
        # cp: context parallel. tp: Megatron sequence parallelism — the
        # residual stream is seq-sharded over 'model' between TP regions
        # (otherwise saved activations are replicated over the model axis:
        # 16 GiB/dev on llama3-8b train_4k; EXPERIMENTS.md §Dry-run).
        return ("model",) if self.profile in ("cp", "tp") else ()

    def ce_logits_spec(self, b: int) -> P:
        """Per-chunk CE logits sharding: vocab over 'model' where the head
        is column-parallel (tp) or ZeRO'd (cp); batch-only for dp (otherwise
        SPMD reshards the whole batch to replicate it — measured 7.8 GiB
        chunks on recurrentgemma-2b)."""
        b_ax = self.batch_axes(b) or None
        if self.profile == "dp":
            return P(b_ax, None, None)
        return P(b_ax, None, "model")

    def act_seq_axes(self) -> Tuple[str, ...]:
        """Sharding of the ACTIVATION sequence dim (decode activations have
        S=1 and are unsharded; seq_axes() then refers to the KV cache)."""
        return () if self.kind == "decode" else self.seq_axes()

    def vocab_axes(self) -> Tuple[str, ...]:
        return ("model",) if self.profile == "tp" else ()

    def hidden_spec(self, b: int) -> P:
        sa = self.seq_axes() if self.kind != "decode" else ()
        return P(self.batch_axes(b) or None, sa or None, None)

    def constrain(self, x, spec: P):
        if self.plain:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    # ----- params -----------------------------------------------------------
    def spec_for(self, pd: PD) -> P:
        axes, shape = pd.axes, pd.shape
        out = [None] * len(shape)
        if self.profile == "tp":
            used_model = False
            # experts take priority for the 'model' axis (EP), then TP axes
            for i, a in enumerate(axes):
                if a == "experts" and shape[i] % self.msize == 0:
                    out[i] = "model"
                    used_model = True
            for i, a in enumerate(axes):
                if used_model:
                    break
                if a in TENSOR_AXES and shape[i] % self.msize == 0:
                    out[i] = "model"
                    used_model = True
            if self.fsdp:
                for i, a in enumerate(axes):
                    if a in FSDP_AXES and out[i] is None and shape[i] % self.dsize == 0:
                        out[i] = "data"
                        break
                else:
                    # MoE expert weights: ZeRO their fan-in dim over 'data'
                    for i, a in enumerate(axes):
                        if (
                            a in ("embed", "ff")
                            and out[i] is None
                            and shape[i] % self.dsize == 0
                        ):
                            out[i] = "data"
                            break
        elif self.profile == "cp":
            # EP for experts, ZeRO-3 for everything else
            used_model = False
            for i, a in enumerate(axes):
                if a == "experts" and shape[i] % self.msize == 0:
                    out[i] = "model"
                    used_model = True
            placed = False
            for i, a in enumerate(axes):
                if a is None or a == "layers" or out[i] is not None:
                    continue
                if not used_model and shape[i] % (self.dsize * self.msize) == 0:
                    out[i] = ("data", "model")
                    placed = True
                    break
            if not placed:
                for i, a in enumerate(axes):
                    if a is None or a == "layers" or out[i] is not None:
                        continue
                    if self.fsdp and shape[i] % self.dsize == 0:
                        out[i] = "data"
                        break
        else:  # dp
            if self.fsdp:
                for i, a in enumerate(axes):
                    if a is not None and a != "layers" and shape[i] % self.dsize == 0:
                        out[i] = "data"
                        break
        return P(*out)

    def param_specs(self, defs):
        return jax.tree.map(self.spec_for, defs, is_leaf=is_pd)

    def param_shardings(self, defs):
        return jax.tree.map(
            lambda pd: NamedSharding(self.mesh, self.spec_for(pd)), defs, is_leaf=is_pd
        )

    # ----- MoE groups ---------------------------------------------------------
    def moe_token_axes(self, b: int) -> Tuple[str, ...]:
        return self.batch_axes(b) + self.act_seq_axes()

    def moe_group_count(self, tokens: int, b: int, target_group: int = 4096) -> int:
        shards = 1
        for a in self.moe_token_axes(b):
            shards *= axis_size(self.mesh, a)
        g = shards
        while tokens // g > target_group and tokens % (g * 2) == 0:
            g *= 2
        return g

    def expert_wspec(self) -> P:
        """Expert weight spec: EP over 'model' + ZeRO fan-in over 'data'."""
        return P("model", "data" if self.fsdp else None, None)


def make_policy(cfg, mesh: Mesh, kind: str, plain: bool = False) -> Policy:
    msize = axis_size(mesh, "model")
    rep = 1
    if cfg.shard_profile == "tp" and cfg.num_kv_heads % msize != 0:
        rep = msize // math.gcd(cfg.num_kv_heads, msize)
    return Policy(
        profile=cfg.shard_profile,
        mesh=mesh,
        kind=kind,
        fsdp=cfg.fsdp,
        kv_repeat=rep,
        plain=plain,
    )
