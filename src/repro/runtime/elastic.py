"""Elastic scaling: re-mesh and re-shard live training state.

On a node-count change the runtime rebuilds the mesh/policy pair, recomputes
every leaf's NamedSharding under the new mesh, and ``device_put``s the state
across — on real hardware this lowers to resharding collectives (the xDFS
session re-negotiation: same blocks, new channel map). The data stream
resumes at the same step (pure function of step), so elasticity is
semantically invisible to the optimizer.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax

from repro.models.transformer import build_model
from repro.runtime.train import TrainState, state_shardings


def remesh(cfg, devices, kind: str = "train"):
    """Build the largest (data, model)-factored mesh for a device list."""
    n = len(devices)
    model_axis = 1
    for m in (16, 8, 4, 2, 1):
        if n % m == 0 and (cfg.shard_profile != "dp" or m == 1):
            model_axis = m
            break
    import numpy as np

    mesh_devices = np.asarray(devices).reshape(n // model_axis, model_axis)
    from jax.sharding import Mesh

    return Mesh(mesh_devices, ("data", "model"))


def reshard_state(
    state: TrainState, model_old, cfg, new_mesh, optimizer
) -> Tuple[TrainState, Any]:
    """Move a TrainState onto a new mesh; returns (state, new_model)."""
    new_model = build_model(cfg, new_mesh, "train")
    ss = state_shardings(new_model, optimizer)
    new_state = jax.tree.map(lambda x, sh: jax.device_put(x, sh), state, ss)
    return new_state, new_model
