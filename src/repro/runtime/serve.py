"""Serving steps: prefill and single-token decode, pjit'd with explicit
shardings. Decode uses the sequence-sharded flash-decoding cache layout
(batch over 'data'/'pod', cache sequence over 'model') — see DESIGN.md.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


def _sh(mesh, tree):
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec), tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def jit_prefill_step(model, shape):
    mesh = model.policy.mesh
    in_sh = _sh(mesh, model.input_specs(shape))
    return jax.jit(model.prefill, in_shardings=(None, in_sh))


def jit_decode_step(model, shape):
    mesh = model.policy.mesh
    in_sh = _sh(mesh, model.input_specs(shape))
    cache_sh = in_sh["caches"]
    return jax.jit(
        model.decode_step,
        in_shardings=(None, in_sh),
        out_shardings=(None, cache_sh),
        donate_argnums=(),
    )
