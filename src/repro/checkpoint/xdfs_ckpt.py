"""Sharded checkpointing over the xDFS session API.

Save = one persistent upload session: every pytree leaf (and the JSON
manifest) is ``put`` through an ``XdfsClient`` as an in-memory source, so
all checkpoint bytes flow through the negotiated multi-channel session —
one negotiation per save, EOFR channel reuse between leaves, and the
MTEDP single-writer vectored sink on the server side. Restore = one
download session: ``get_bytes`` futures pipeline the leaf reads.

Layout:
  <dir>/step_<N>.tmp/...   (in-flight)
  <dir>/step_<N>/manifest.json + <leaf_id>.bin   (committed via atomic rename)

Fault-tolerance invariants (tested):
  * a torn save never becomes visible (atomic rename of the step dir);
  * restore picks the newest COMPLETE step;
  * checksum mismatch -> that step is rejected and the previous one loads;
  * keep_last bounds disk usage.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from contextlib import contextmanager
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

BLOCK = 4 << 20
N_CHANNELS = 2
ENGINE = "mtedp"


@contextmanager
def _session(root: Path):
    """A loopback xDFS session rooted at ``root`` (server + client pair)."""
    from repro.core.api import XdfsClient, XdfsServer

    srv = XdfsServer(engine=ENGINE, root=str(root)).start()
    cli = XdfsClient.connect(
        srv.address, n_channels=N_CHANNELS, engine=ENGINE, block_size=BLOCK
    )
    try:
        yield cli
    finally:
        cli.close()
        srv.stop()


def _leaf_files(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for i, (path, leaf) in enumerate(flat):
        name = f"leaf_{i:05d}.bin"
        out.append((jax.tree_util.keystr(path), name, leaf))
    return out


def save(tree: Any, directory: str, step: int, keep_last: int = 3) -> str:
    """Blocking sharded save; returns the committed directory."""
    base = Path(directory)
    base.mkdir(parents=True, exist_ok=True)
    rel = f"step_{step:08d}.tmp"
    tmp = base / rel
    final = base / f"step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    manifest = {"step": step, "leaves": []}
    with _session(base) as cli:
        # one negotiation for the whole step; leaves pipeline depth-2
        # through the session worker (bounded host memory: only the leaf in
        # flight and the one being prepared are materialized)
        prev = None
        for keypath, fname, leaf in _leaf_files(tree):
            arr = np.asarray(jax.device_get(leaf))
            raw = arr.tobytes()
            manifest["leaves"].append(
                {
                    "key": keypath,
                    "file": fname,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "crc32": zlib.crc32(raw) & 0xFFFFFFFF,
                }
            )
            fut = cli.put(None, f"{rel}/{fname}", data=raw)
            if prev is not None:
                prev.result()
            prev = fut
        if prev is not None:
            prev.result()
        cli.put(None, f"{rel}/manifest.json",
                data=json.dumps(manifest).encode()).result()
    if final.exists():  # re-save after fault recovery: replace the old step
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic commit
    _gc(base, keep_last)
    return str(final)


def _gc(base: Path, keep_last: int):
    steps = sorted(p for p in base.glob("step_*") if not p.name.endswith(".tmp"))
    for p in steps[:-keep_last]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    base = Path(directory)
    if not base.exists():
        return None
    steps = []
    for p in sorted(base.glob("step_*")):
        if p.name.endswith(".tmp") or not (p / "manifest.json").exists():
            continue
        steps.append(int(p.name.split("_")[1]))
    return steps[-1] if steps else None


def restore(directory: str, like: Any, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``like`` (ShapeDtypeStructs or arrays).

    Walks back to older steps if the newest is corrupt (checksum)."""
    base = Path(directory)
    candidates = sorted(
        int(p.name.split("_")[1])
        for p in base.glob("step_*")
        if not p.name.endswith(".tmp") and (p / "manifest.json").exists()
    )
    if step is not None:
        candidates = [s for s in candidates if s == step]
    last_err: Optional[Exception] = None
    for s in reversed(candidates):
        try:
            return _restore_one(base / f"step_{s:08d}", like, shardings), s
        except Exception as e:  # corrupt step: fall back
            last_err = e
    raise FileNotFoundError(f"no restorable checkpoint in {directory}: {last_err}")


def _restore_one(d: Path, like: Any, shardings: Any):
    manifest = json.loads((d / "manifest.json").read_text())
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    sh_leaves = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else
        [None] * len(leaves_like)
    )
    if len(manifest["leaves"]) != len(leaves_like):
        raise ValueError(
            f"leaf count mismatch: ckpt {len(manifest['leaves'])} vs {len(leaves_like)}"
        )
    def finish(meta, raw, sh):
        if (zlib.crc32(raw) & 0xFFFFFFFF) != meta["crc32"]:
            raise IOError(f"checksum mismatch in {meta['file']}")
        arr = np.frombuffer(raw, dtype=meta["dtype"]).reshape(meta["shape"])
        return jax.device_put(arr, sh) if sh is not None else jax.device_put(arr)

    out = []
    with _session(d) as cli:
        # depth-2 pipeline: leaf k+1 streams while leaf k is checksummed
        # and placed on device, so only ~one leaf is resident at a time
        fut = prev = None
        for meta, sh in zip(manifest["leaves"], sh_leaves):
            nxt = cli.get_bytes(meta["file"])
            if fut is not None:
                out.append(finish(prev[0], fut.result().data, prev[1]))
            fut, prev = nxt, (meta, sh)
        if fut is not None:
            out.append(finish(prev[0], fut.result().data, prev[1]))
    return jax.tree_util.tree_unflatten(treedef, out)
