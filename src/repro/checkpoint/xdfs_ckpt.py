"""Sharded checkpointing over the xDFS session API.

Save = one persistent upload session: every pytree leaf (and the JSON
manifest) is ``put`` through an ``XdfsClient`` as an in-memory source, so
all checkpoint bytes flow through the negotiated multi-channel session —
one negotiation per save, EOFR channel reuse between leaves, and the
MTEDP single-writer vectored sink on the server side. Restore = one
download session: ``get_bytes`` futures pipeline the leaf reads.

Layout:
  <dir>/step_<N>.tmp/...   (in-flight)
  <dir>/step_<N>/manifest.json + <leaf_id>.bin   (committed via atomic rename)

Fault-tolerance invariants (tested):
  * a torn save never becomes visible (atomic rename of the step dir);
  * restore picks the newest COMPLETE step;
  * checksum mismatch -> that step is rejected and the previous one loads;
  * keep_last bounds disk usage.

Cluster mode (opt-in): pass ``cluster=ClusterClient(...)`` — or just a
metanode address / list of metanode addresses, and a client is built
and closed per call — to ``save`` / ``restore`` / ``latest_step`` and
every leaf stripes across the fleet of data nodes with the MetaNode's
replication factor — sharded JAX checkpoint shards become replicated
cluster blocks, and a data node dying between save and restore costs
nothing. With a journaled, multi-metanode control plane, so does the
MetaNode: commits are write-ahead journaled and standbys take over, so
a checkpoint save survives metanode death mid-run and a restore works
against whichever metanode currently leads. ``directory`` then names a
prefix in the cluster namespace instead of a local path; the manifest
is written LAST, so it is the commit point (restore only considers
steps whose manifest exists — the same torn-save invariant as the
atomic rename, without needing a rename primitive). The single-node
local path stays the default and is untouched.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from contextlib import contextmanager
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

BLOCK = 4 << 20
N_CHANNELS = 2
ENGINE = "mtedp"


@contextmanager
def _session(root: Path, integrity: bool = False):
    """A loopback xDFS session rooted at ``root`` (server + client pair)."""
    from repro.core.api import XdfsClient, XdfsServer

    srv = XdfsServer(engine=ENGINE, root=str(root)).start()
    cli = XdfsClient.connect(
        srv.address, n_channels=N_CHANNELS, engine=ENGINE, block_size=BLOCK,
        integrity=integrity,
    )
    try:
        yield cli
    finally:
        cli.close()
        srv.stop()


def _leaf_files(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for i, (path, leaf) in enumerate(flat):
        name = f"leaf_{i:05d}.bin"
        out.append((jax.tree_util.keystr(path), name, leaf))
    return out


def _step_prefix(directory: str, step: int) -> str:
    return f"{directory.rstrip('/')}/step_{step:08d}"


@contextmanager
def _as_client(cluster):
    """Accept a live ``ClusterClient`` (caller owns it) or one-or-more
    metanode addresses (a throwaway failover client is built and closed
    around the call)."""
    if hasattr(cluster, "put") and hasattr(cluster, "list"):
        yield cluster
        return
    from repro.cluster import ClusterClient

    cli = ClusterClient(cluster)
    try:
        yield cli
    finally:
        cli.close()


def _cluster_steps(directory: str, cluster) -> list:
    """Committed steps in the cluster namespace = those whose manifest
    (the last file written) exists."""
    prefix = directory.rstrip("/") + "/step_"
    steps = set()
    for name in cluster.list(prefix):
        rest = name[len(prefix):]
        if rest.endswith("/manifest.json"):
            steps.add(int(rest.split("/")[0]))
    return sorted(steps)


def _save_cluster(tree: Any, directory: str, step: int, keep_last: int,
                  cluster) -> str:
    prefix = _step_prefix(directory, step)
    manifest = {"step": step, "leaves": []}
    for keypath, fname, leaf in _leaf_files(tree):
        arr = np.asarray(jax.device_get(leaf))
        raw = arr.tobytes()
        manifest["leaves"].append(
            {
                "key": keypath,
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc32": zlib.crc32(raw) & 0xFFFFFFFF,
            }
        )
        cluster.put(f"{prefix}/{fname}", data=raw)
    # manifest LAST = the commit point (restore ignores manifest-less steps)
    cluster.put(f"{prefix}/manifest.json",
                data=json.dumps(manifest).encode())
    for old in _cluster_steps(directory, cluster)[:-keep_last]:
        for name in cluster.list(_step_prefix(directory, old) + "/"):
            cluster.delete(name)
    return prefix


def _restore_one_cluster(directory: str, step: int, like: Any,
                         shardings: Any, cluster):
    prefix = _step_prefix(directory, step)
    manifest = json.loads(cluster.get(f"{prefix}/manifest.json"))
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    sh_leaves = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else
        [None] * len(leaves_like)
    )
    if len(manifest["leaves"]) != len(leaves_like):
        raise ValueError(
            f"leaf count mismatch: ckpt {len(manifest['leaves'])} "
            f"vs {len(leaves_like)}"
        )
    out = []
    for meta, sh in zip(manifest["leaves"], sh_leaves):
        raw = cluster.get(f"{prefix}/{meta['file']}")
        if (zlib.crc32(raw) & 0xFFFFFFFF) != meta["crc32"]:
            raise IOError(f"checksum mismatch in {meta['file']}")
        arr = np.frombuffer(raw, dtype=meta["dtype"]).reshape(meta["shape"])
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def save(tree: Any, directory: str, step: int, keep_last: int = 3,
         cluster=None, resume: bool = False, integrity: bool = False) -> str:
    """Blocking sharded save; returns the committed directory.

    ``cluster`` (opt-in): a ``repro.cluster.ClusterClient`` — leaves
    stripe across the fleet of data nodes instead of a local step dir.

    ``resume`` (opt-in, implies ``integrity``): a save interrupted
    mid-step left its ``.tmp`` dir and per-file resume sidecars behind;
    a re-save with ``resume=True`` keeps them and re-``put``\\ s every
    leaf with the RESUME protocol, so complete leaves cost a CRC
    exchange and zero data bytes, and a torn leaf only re-sends its
    missing/stale blocks.
    """
    if cluster is not None:
        if resume:
            raise ValueError("resume is not supported for cluster saves")
        with _as_client(cluster) as cli:
            return _save_cluster(tree, directory, step, keep_last, cli)
    integrity = integrity or resume
    base = Path(directory)
    base.mkdir(parents=True, exist_ok=True)
    rel = f"step_{step:08d}.tmp"
    tmp = base / rel
    final = base / f"step_{step:08d}"
    if tmp.exists() and not resume:
        shutil.rmtree(tmp)
    tmp.mkdir(exist_ok=True)
    manifest = {"step": step, "leaves": []}
    with _session(base, integrity=integrity) as cli:
        # one negotiation for the whole step; leaves pipeline depth-2
        # through the session worker (bounded host memory: only the leaf in
        # flight and the one being prepared are materialized)
        prev = None
        for keypath, fname, leaf in _leaf_files(tree):
            arr = np.asarray(jax.device_get(leaf))
            raw = arr.tobytes()
            manifest["leaves"].append(
                {
                    "key": keypath,
                    "file": fname,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "crc32": zlib.crc32(raw) & 0xFFFFFFFF,
                }
            )
            fut = cli.put(None, f"{rel}/{fname}", data=raw, resume=resume)
            if prev is not None:
                prev.result()
            prev = fut
        if prev is not None:
            prev.result()
        cli.put(None, f"{rel}/manifest.json",
                data=json.dumps(manifest).encode(), resume=resume).result()
    # integrity puts keep resume sidecars next to the data files; a fully
    # landed step no longer needs them, so don't commit them
    from repro.core.resume import SIDECAR_SUFFIX

    for sc in tmp.glob("*" + SIDECAR_SUFFIX):
        sc.unlink()
    if final.exists():  # re-save after fault recovery: replace the old step
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic commit
    _gc(base, keep_last)
    return str(final)


def _gc(base: Path, keep_last: int):
    steps = sorted(p for p in base.glob("step_*") if not p.name.endswith(".tmp"))
    for p in steps[:-keep_last]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(directory: str, cluster=None) -> Optional[int]:
    if cluster is not None:
        with _as_client(cluster) as cli:
            steps = _cluster_steps(directory, cli)
        return steps[-1] if steps else None
    base = Path(directory)
    if not base.exists():
        return None
    steps = []
    for p in sorted(base.glob("step_*")):
        if p.name.endswith(".tmp") or not (p / "manifest.json").exists():
            continue
        steps.append(int(p.name.split("_")[1]))
    return steps[-1] if steps else None


def restore(directory: str, like: Any, step: Optional[int] = None,
            shardings: Any = None, cluster=None) -> Any:
    """Restore into the structure of ``like`` (ShapeDtypeStructs or arrays).

    Walks back to older steps if the newest is corrupt (checksum).
    ``cluster`` (opt-in): restore from the cluster namespace instead of
    a local directory — per-block CRCs and replica failover come from
    the ``ClusterClient``, and the leaf-level checksum walk-back across
    steps is the same as the local path."""
    if cluster is not None:
        with _as_client(cluster) as cli:
            candidates = _cluster_steps(directory, cli)
            if step is not None:
                candidates = [s for s in candidates if s == step]
            last_err: Optional[Exception] = None
            for s in reversed(candidates):
                try:
                    return _restore_one_cluster(directory, s, like,
                                                shardings, cli), s
                except Exception as e:  # corrupt/lost step: fall back
                    last_err = e
        raise FileNotFoundError(
            f"no restorable checkpoint under {directory!r} in cluster: "
            f"{last_err}")
    base = Path(directory)
    candidates = sorted(
        int(p.name.split("_")[1])
        for p in base.glob("step_*")
        if not p.name.endswith(".tmp") and (p / "manifest.json").exists()
    )
    if step is not None:
        candidates = [s for s in candidates if s == step]
    last_err: Optional[Exception] = None
    for s in reversed(candidates):
        try:
            return _restore_one(base / f"step_{s:08d}", like, shardings), s
        except Exception as e:  # corrupt step: fall back
            last_err = e
    raise FileNotFoundError(f"no restorable checkpoint in {directory}: {last_err}")


def _restore_one(d: Path, like: Any, shardings: Any):
    manifest = json.loads((d / "manifest.json").read_text())
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    sh_leaves = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else
        [None] * len(leaves_like)
    )
    if len(manifest["leaves"]) != len(leaves_like):
        raise ValueError(
            f"leaf count mismatch: ckpt {len(manifest['leaves'])} vs {len(leaves_like)}"
        )
    def finish(meta, raw, sh):
        if (zlib.crc32(raw) & 0xFFFFFFFF) != meta["crc32"]:
            raise IOError(f"checksum mismatch in {meta['file']}")
        arr = np.frombuffer(raw, dtype=meta["dtype"]).reshape(meta["shape"])
        return jax.device_put(arr, sh) if sh is not None else jax.device_put(arr)

    out = []
    with _session(d) as cli:
        # depth-2 pipeline: leaf k+1 streams while leaf k is checksummed
        # and placed on device, so only ~one leaf is resident at a time
        fut = prev = None
        for meta, sh in zip(manifest["leaves"], sh_leaves):
            nxt = cli.get_bytes(meta["file"])
            if fut is not None:
                out.append(finish(prev[0], fut.result().data, prev[1]))
            fut, prev = nxt, (meta, sh)
        if fut is not None:
            out.append(finish(prev[0], fut.result().data, prev[1]))
    return jax.tree_util.tree_unflatten(treedef, out)
