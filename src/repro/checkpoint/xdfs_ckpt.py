"""Sharded checkpointing over the xDFS transfer machinery.

Save = FTSM upload (device -> host -> disk): each pytree leaf is written in
block_size chunks through a single-writer sink with coalesced vectored I/O
(core.transfer.Sink), framed by a JSON manifest carrying the tree structure,
shapes/dtypes, the step, and per-leaf checksums. Restore = download.

Layout:
  <dir>/step_<N>.tmp/...   (in-flight)
  <dir>/step_<N>/manifest.json + <leaf_id>.bin   (committed via atomic rename)

Fault-tolerance invariants (tested):
  * a torn save never becomes visible (atomic rename of the step dir);
  * restore picks the newest COMPLETE step;
  * checksum mismatch -> that step is rejected and the previous one loads;
  * keep_last bounds disk usage.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from repro.core.ringbuf import BlockPool
from repro.core.transfer import Sink

BLOCK = 4 << 20


def _leaf_files(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for i, (path, leaf) in enumerate(flat):
        name = f"leaf_{i:05d}.bin"
        out.append((jax.tree_util.keystr(path), name, leaf))
    return out


def save(tree: Any, directory: str, step: int, keep_last: int = 3) -> str:
    """Blocking sharded save; returns the committed directory."""
    base = Path(directory)
    base.mkdir(parents=True, exist_ok=True)
    tmp = base / f"step_{step:08d}.tmp"
    final = base / f"step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    manifest = {"step": step, "leaves": []}
    for keypath, fname, leaf in _leaf_files(tree):
        arr = np.asarray(jax.device_get(leaf))
        raw = arr.tobytes()
        sink = Sink(str(tmp / fname), len(raw))
        # stream in xDFS blocks through the single-writer vectored path
        blocks = [
            (off, min(BLOCK, len(raw) - off), bytearray(raw[off : off + BLOCK]))
            for off in range(0, max(len(raw), 1), BLOCK)
            if off < len(raw)
        ]
        sink.writev_coalesced(blocks)
        sink.close()
        manifest["leaves"].append(
            {
                "key": keypath,
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc32": zlib.crc32(raw) & 0xFFFFFFFF,
            }
        )
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():  # re-save after fault recovery: replace the old step
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic commit
    _gc(base, keep_last)
    return str(final)


def _gc(base: Path, keep_last: int):
    steps = sorted(p for p in base.glob("step_*") if not p.name.endswith(".tmp"))
    for p in steps[:-keep_last]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    base = Path(directory)
    if not base.exists():
        return None
    steps = []
    for p in sorted(base.glob("step_*")):
        if p.name.endswith(".tmp") or not (p / "manifest.json").exists():
            continue
        steps.append(int(p.name.split("_")[1]))
    return steps[-1] if steps else None


def restore(directory: str, like: Any, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``like`` (ShapeDtypeStructs or arrays).

    Walks back to older steps if the newest is corrupt (checksum)."""
    base = Path(directory)
    candidates = sorted(
        int(p.name.split("_")[1])
        for p in base.glob("step_*")
        if not p.name.endswith(".tmp") and (p / "manifest.json").exists()
    )
    if step is not None:
        candidates = [s for s in candidates if s == step]
    last_err: Optional[Exception] = None
    for s in reversed(candidates):
        try:
            return _restore_one(base / f"step_{s:08d}", like, shardings), s
        except Exception as e:  # corrupt step: fall back
            last_err = e
    raise FileNotFoundError(f"no restorable checkpoint in {directory}: {last_err}")


def _restore_one(d: Path, like: Any, shardings: Any):
    manifest = json.loads((d / "manifest.json").read_text())
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    sh_leaves = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else
        [None] * len(leaves_like)
    )
    if len(manifest["leaves"]) != len(leaves_like):
        raise ValueError(
            f"leaf count mismatch: ckpt {len(manifest['leaves'])} vs {len(leaves_like)}"
        )
    out = []
    for meta, like_leaf, sh in zip(manifest["leaves"], leaves_like, sh_leaves):
        raw = (d / meta["file"]).read_bytes()
        if (zlib.crc32(raw) & 0xFFFFFFFF) != meta["crc32"]:
            raise IOError(f"checksum mismatch in {meta['file']}")
        arr = np.frombuffer(raw, dtype=meta["dtype"]).reshape(meta["shape"])
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
