"""Asynchronous checkpointing — the paper's disk thread, verbatim.

``AsyncCheckpointer.save`` snapshots device arrays to host (the only
synchronous part) and hands the write to a background disk thread through a
bounded queue; training continues while blocks drain to disk. ``wait()``
joins all outstanding writes (call before shutdown / before depending on the
checkpoint being on disk).
"""
from __future__ import annotations

import queue
import threading
from concurrent.futures import Future
from typing import Any, List, Optional

import jax
import numpy as np

from repro.checkpoint import xdfs_ckpt


class AsyncCheckpointer:
    def __init__(self, directory: str, keep_last: int = 3, depth: int = 2):
        self.directory = directory
        self.keep_last = keep_last
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._futures: List[Future] = []
        self._thread = threading.Thread(target=self._disk_thread, daemon=True)
        self._thread.start()

    def _disk_thread(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            tree, step, fut = item
            try:
                fut.set_result(
                    xdfs_ckpt.save(tree, self.directory, step, self.keep_last)
                )
            except BaseException as e:
                fut.set_exception(e)

    def save(self, tree: Any, step: int) -> Future:
        """Non-blocking: snapshot to host, enqueue for the disk thread."""
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        fut: Future = Future()
        self._futures.append(fut)
        self._q.put((host_tree, step, fut))
        return fut

    def wait(self):
        for fut in self._futures:
            fut.result()  # re-raises disk-thread failures
        self._futures.clear()

    def close(self):
        self.wait()
        self._q.put(None)
        self._thread.join(timeout=5)
