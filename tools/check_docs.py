"""Docs lint: code fences and internal links must resolve.

  python tools/check_docs.py README.md docs/*.md

Checks, per markdown file:

* every ``` code fence is closed (odd fence counts are broken docs);
* every internal markdown link ``[text](target)`` resolves: the target
  file exists relative to the doc (http(s)/mailto links are skipped),
  and a ``#fragment`` matches a heading in the target file using
  GitHub's slugification (lowercase, spaces to dashes, punctuation
  dropped).

Exits non-zero listing every violation. No dependencies beyond the
stdlib, so CI and tests can both run it.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path
from typing import List

FENCE = re.compile(r"^\s*(```|~~~)")
# [text](target) — ignores images' leading ! by matching it away
LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
HEADING = re.compile(r"^(#{1,6})\s+(.*?)\s*$")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: strip markdown code ticks, lowercase, drop
    punctuation, spaces to dashes."""
    text = heading.replace("`", "")
    text = text.lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def _strip_fenced(lines: List[str]) -> List[str]:
    """Drop fenced-code-block interiors so fences' content (e.g. ASCII
    diagrams containing brackets) is not link-checked."""
    out, in_fence = [], False
    for line in lines:
        if FENCE.match(line):
            in_fence = not in_fence
            continue
        if not in_fence:
            out.append(line)
    return out


def _headings(path: Path) -> List[str]:
    slugs = []
    in_fence = False
    for line in path.read_text().splitlines():
        if FENCE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING.match(line)
        if m:
            slugs.append(github_slug(m.group(2)))
    return slugs


def check_file(path: Path) -> List[str]:
    errors: List[str] = []
    try:
        lines = path.read_text().splitlines()
    except OSError as e:
        return [f"{path}: unreadable: {e}"]

    n_fences = sum(1 for line in lines if FENCE.match(line))
    if n_fences % 2:
        errors.append(f"{path}: odd number of code fences ({n_fences}) — "
                      "an unclosed ``` block")

    for i, line in enumerate(_strip_fenced(lines), 1):
        for m in LINK.finditer(line):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            file_part, _, fragment = target.partition("#")
            if file_part:
                dest = (path.parent / file_part).resolve()
                if not dest.exists():
                    errors.append(
                        f"{path}: broken link {target!r} "
                        f"(no such file {file_part!r})")
                    continue
            else:
                dest = path.resolve()
            if fragment and dest.suffix == ".md":
                if fragment not in _headings(dest):
                    errors.append(
                        f"{path}: broken anchor {target!r} "
                        f"(no heading slugs to {fragment!r} in {dest.name})")
    return errors


def main(argv: List[str]) -> int:
    if not argv:
        print("usage: python tools/check_docs.py FILE.md [FILE.md ...]",
              file=sys.stderr)
        return 2
    errors: List[str] = []
    for name in argv:
        errors.extend(check_file(Path(name)))
    for e in errors:
        print(f"DOCS ERROR: {e}", file=sys.stderr)
    if not errors:
        print(f"docs OK ({len(argv)} files)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
